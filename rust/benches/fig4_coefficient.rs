//! Figure 4 — statistics of the adaptive-scaling coefficient
//! `sqrt(v̂_Adam) / sqrt(v̂_AdamA)` during training.
//!
//! Paper: tracked on ResNet-50/CIFAR-100, the coefficient stays within
//! ~1% of 1.0. Here we track it on *real* gradients captured from the
//! tiny transformer (per-micro-batch, via a gradient sink), maintaining
//! both second-moment recursions side by side, and additionally sweep the
//! two analytic regimes (noise- vs mean-dominated — see
//! python/tests/test_adama_semantics.py for why the ratio → sqrt(N) in
//! the fully-correlated limit).

use adama::config::OptimizerKind;
use adama::data::MarkovCorpus;
use adama::optim::host_math;
use adama::tensor::Rng;
use adama::Trainer;

#[path = "support/mod.rs"]
mod support;
use support::{banner, cfg, lib_or_exit, quick};

const B2: f32 = 0.999;

fn coeff_stats(v_adam: &[f32], v_adama: &[f32]) -> (f32, f32, f32) {
    let (mut sum, mut lo, mut hi, mut n) = (0.0f64, f32::INFINITY, 0.0f32, 0usize);
    for (&a, &b) in v_adam.iter().zip(v_adama) {
        if a > 1e-12 && b > 1e-12 {
            let c = (a / b).sqrt();
            sum += c as f64;
            lo = lo.min(c);
            hi = hi.max(c);
            n += 1;
        }
    }
    ((sum / n.max(1) as f64) as f32, lo, hi)
}

fn main() {
    let lib = lib_or_exit();
    let n = 8usize;
    let steps = if quick() { 5 } else { 25 };

    banner("Figure 4: sqrt(v_Adam)/sqrt(v_AdamA) on real tiny-transformer grads");
    let mut trainer =
        Trainer::new(lib.clone(), cfg("tiny", OptimizerKind::AdamA, n, 42)).unwrap();
    let h = trainer.spec().hyper.clone();
    let mut corpus = MarkovCorpus::new(h.vocab, 7, 4242);
    let total: usize = trainer.spec().total_params();
    let n_layers = trainer.spec().layers.len();
    let offsets: Vec<usize> = {
        let mut off = vec![0usize; n_layers + 1];
        for (i, l) in trainer.spec().layers.iter().enumerate() {
            off[i + 1] = off[i] + l.flat_len;
        }
        off
    };

    let mut v_adam = vec![0.0f32; total];
    let mut v_adama = vec![0.0f32; total];
    println!("step,mean,min,max");
    for step in 1..=steps {
        let mbs = corpus.minibatch(n, h.microbatch, h.seq);
        let mut gsum = vec![0.0f32; total]; // Adam: (Σ g/N)²
        host_math::scale(&mut v_adama, B2);
        host_math::scale(&mut v_adam, B2);
        let (core, _opt) = trainer.parts_mut();
        for mb in &mbs {
            core.run_microbatch(mb, &mut |layer, grad| {
                let o = offsets[layer];
                for (i, g) in grad.iter().enumerate() {
                    let sg = g / n as f32;
                    gsum[o + i] += sg;
                    v_adama[o + i] += (1.0 - B2) * sg * sg; // AdamA: Σ(g/N)²
                }
                Ok(())
            })
            .unwrap();
        }
        for i in 0..total {
            v_adam[i] += (1.0 - B2) * gsum[i] * gsum[i];
        }
        let (mean, lo, hi) = coeff_stats(&v_adam, &v_adama);
        println!("{step},{mean:.4},{lo:.4},{hi:.4}");
        if step == steps {
            assert!(
                mean > 0.5 && mean < (n as f32).sqrt() + 0.2,
                "coefficient out of theoretical range: {mean}"
            );
        }
    }

    banner("analytic regimes (synthetic grads, N=8, d=4096)");
    println!("{:<18} {:>8} {:>8} {:>8}", "regime", "mean", "min", "max");
    for (name, mu, sigma) in
        [("noise-dominated", 0.05f32, 1.0f32), ("balanced", 0.5, 1.0), ("mean-dominated", 1.0, 0.1)]
    {
        let d = 4096usize;
        let mut rng = Rng::new(1);
        let base: Vec<f32> = (0..d).map(|_| mu * rng.normal()).collect();
        let mut va = vec![0.0f32; d];
        let mut vb = vec![0.0f32; d];
        for _ in 0..50 {
            host_math::scale(&mut va, B2);
            host_math::scale(&mut vb, B2);
            let mut gsum = vec![0.0f32; d];
            for _ in 0..8 {
                for i in 0..d {
                    let g = (base[i] + sigma * rng.normal()) / 8.0;
                    gsum[i] += g;
                    vb[i] += (1.0 - B2) * g * g;
                }
            }
            for i in 0..d {
                va[i] += (1.0 - B2) * gsum[i] * gsum[i];
            }
        }
        let (mean, lo, hi) = coeff_stats(&va, &vb);
        println!("{name:<18} {mean:>8.4} {lo:>8.4} {hi:>8.4}");
    }
    println!(
        "\npaper's regime is noise-dominated (large-scale SGD): coefficient ≈ 1 within ~1%"
    );
}
