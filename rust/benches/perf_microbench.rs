//! Perf microbenchmarks — the §Perf instrument (EXPERIMENTS.md).
//!
//! Times the building blocks of the hot path in isolation:
//!   * chunked optimizer kernels (PJRT) vs host loops, per chunk size;
//!   * model artifacts (block fwd/bwd, head, embed);
//!   * a full tiny train step (end-to-end floor).
//!
//! Run before/after each optimization; record deltas in EXPERIMENTS.md.

use adama::config::{OptimBackend, OptimizerKind};
use adama::data::MarkovCorpus;
use adama::optim::{host_math, ChunkRunner, Hyper};
use adama::tensor::Rng;
use adama::util::stats::bench;
use adama::Trainer;

#[path = "support/mod.rs"]
mod support;
use support::{banner, cfg, lib_or_exit, quick};

fn main() {
    let lib = lib_or_exit();
    let iters = if quick() { 3 } else { 20 };

    banner("optimizer kernels: PJRT chunk call vs host loop (1M elements)");
    println!(
        "{:<14} {:>10} {:>14} {:>14} {:>10}",
        "op", "chunk", "kernel (ms)", "host (ms)", "k/h"
    );
    let n_total = 1 << 20;
    let mut rng = Rng::new(1);
    let mut m: Vec<f32> = (0..n_total).map(|_| rng.normal()).collect();
    let mut v: Vec<f32> = (0..n_total).map(|_| rng.normal().abs()).collect();
    let g: Vec<f32> = (0..n_total).map(|_| rng.normal()).collect();
    let hyper = Hyper { beta1: 0.9, beta2: 0.999, eps: 1e-8 };

    for chunk in lib.manifest().chunk_sizes.clone() {
        let mut runner = ChunkRunner::new(lib.clone(), chunk).unwrap();
        let kt = bench(2, iters, || {
            runner.adama_acc(&mut m, &mut v, &g, 0.25).unwrap();
        });
        let ht = bench(2, iters, || {
            host_math::adama_acc(&mut m, &mut v, &g, 0.25, hyper.beta1, hyper.beta2);
        });
        println!(
            "{:<14} {:>10} {:>14.3} {:>14.3} {:>10.2}",
            "adama_acc",
            chunk,
            1e3 * kt.mean(),
            1e3 * ht.mean(),
            kt.mean() / ht.mean()
        );
    }

    banner("model artifacts (tiny): per-call latency");
    let mut t =
        Trainer::new(lib.clone(), cfg("tiny", OptimizerKind::AdamA, 2, 42)).unwrap();
    let h = t.spec().hyper.clone();
    let mut corpus = MarkovCorpus::new(h.vocab, 7, 1);
    let mb = corpus.microbatch(h.microbatch, h.seq);
    {
        let (core, _) = t.parts_mut();
        let s = bench(2, iters, || {
            core.run_microbatch(&mb, &mut |_, _| Ok(())).unwrap();
        });
        println!(
            "microbatch fwd+bwd (no optimizer): {:.3} ms  (p50 {:.3}, p95 {:.3})",
            1e3 * s.mean(),
            1e3 * s.percentile(50.0),
            1e3 * s.percentile(95.0)
        );
    }

    banner("end-to-end train step (tiny, N=2): kernel vs host optimizer backend");
    for backend in [OptimBackend::Kernel, OptimBackend::Host] {
        let mut c = cfg("tiny", OptimizerKind::AdamA, 2, 42);
        c.backend = backend;
        let mut t = Trainer::new(lib.clone(), c).unwrap();
        let h = t.spec().hyper.clone();
        let mut corpus = MarkovCorpus::new(h.vocab, 7, 1);
        let mbs = corpus.minibatch(2, h.microbatch, h.seq);
        let s = bench(1, iters, || {
            t.train_step(&mbs).unwrap();
        });
        println!("{:?}: {:.2} ms/step", backend, 1e3 * s.mean());
    }

    banner("PJRT execute-call count (engine instrumentation)");
    println!("exec calls so far: {}", lib.engine().exec_calls());
}
