//! Perf microbenchmarks — the §Perf instrument (EXPERIMENTS.md).
//!
//! Times the building blocks of the hot path in isolation:
//!   * chunked optimizer kernels (program dispatch) vs raw host loops,
//!     per chunk size;
//!   * a micro-batch forward+backward over the model programs;
//!   * a full tiny train step (end-to-end floor);
//!   * thread-pool scaling: matmul and the `small` transformer block
//!     forward at 1/2/4 pool threads (per-thread-count rows, so the
//!     speedup is machine-recorded in the trajectory);
//!   * GEMM engines: the packed, cache-blocked engine (`ADAMA_GEMM=packed`)
//!     vs the naive loops across a shape sweep — square, transformer-shaped
//!     skinny/fat, and remainder-heavy odd sizes — with GFLOP/s per row;
//!     a full run **fails** if the packed engine regresses below naive
//!     beyond a 10% noise allowance on any swept shape;
//!   * SIMD dispatch: every optimizer kernel plus the `small` block
//!     forward/backward at `ADAMA_SIMD=scalar` vs the detected level —
//!     and a full (non-`--quick`) run **fails** (non-zero exit) if any
//!     SIMD row regresses below its scalar twin beyond a 10% noise
//!     allowance;
//!   * activation stash vs remat: the `small` block forward+backward
//!     pair at budget 0 (per-layer remat) vs unlimited (stash hit —
//!     backward skips the recompute), at 1 and 4 threads;
//!   * distributed engines: DP state-sync step time under the serial
//!     simulator vs the concurrent fabric at 1/2/4 ranks, plus the
//!     ZeRO-S1+AdamA per-layer overlap flow at 2 ranks (bit-identical
//!     engines — `rust/tests/fabric_parity.rs` — so the rows measure
//!     pure scheduling);
//!   * async issue: ZeRO-S1+AdamA with per-layer reductions handed to the
//!     fabric comm thread (`ADAMA_ASYNC=1` semantics) vs blocking issue,
//!     at 2 and 4 ranks — `zero1_async_vs_sync` rows; a full run **fails**
//!     if async falls below sync beyond a 10% noise allowance;
//!   * checkpoint I/O: `ADAMACK2` full-state container save (serialize +
//!     per-section hash + atomic tmp/rename) and load (parse + hash
//!     re-verify) for the tiny model, with MB/s per row — the cost floor
//!     of a crash-safety cadence (`ADAMA_CKPT_EVERY`);
//!   * serving: the batched KV-cache decode path (`serve::Scheduler`
//!     over a deterministic synthetic load) — tokens/s and p50/p99
//!     request latency at batch 1 vs batch 4, plus an eviction row under
//!     a tight `ADAMA_KV_BUDGET`-style cap; a full run **fails** if
//!     batched serving falls below serial serving beyond a 10% noise
//!     allowance (decode is bit-identical either way —
//!     `rust/tests/serve.rs` — so the rows measure pure scheduling).
//!
//! Besides the human-readable table, writes `BENCH_perf.json` —
//! machine-readable ns/elem per kernel per backend (each row tagged with
//! its pool thread count and SIMD level) — so subsequent PRs have a perf
//! trajectory to regress against.

use adama::collective::{
    run_data_parallel, run_zero1, CollectiveEngine, DpSpec, SyncStrategy, Zero1Spec,
};
use adama::config::{OptimBackend, OptimizerKind};
use adama::coordinator::ServeStats;
use adama::data::MarkovCorpus;
use adama::model::ckpt::TrainState;
use adama::serve::{InferenceEngine, Scheduler, SyntheticLoad};
use adama::optim::{host_math, ChunkRunner, Hyper};
use adama::runtime::hostexec::math;
use adama::runtime::{simd, GemmMode, Library, MemoryPlan, ThreadPool, Value};
use adama::tensor::Rng;
use adama::util::json::{obj, Json};
use adama::util::stats::bench;
use adama::Trainer;

#[path = "support/mod.rs"]
mod support;
use support::{banner, cfg, lib_or_exit, quick};

fn main() {
    let lib = lib_or_exit();
    let iters = if quick() { 3 } else { 20 };
    let platform = lib.executor().platform();
    let mut results: Vec<Json> = Vec::new();
    let mut simd_regressions: Vec<String> = Vec::new();

    banner("optimizer kernels: chunked program dispatch vs raw host loop (1M elements)");
    println!(
        "{:<14} {:>10} {:>14} {:>14} {:>10}",
        "op", "chunk", "kernel (ms)", "host (ms)", "k/h"
    );
    let n_total: usize = 1 << 20;
    let mut rng = Rng::new(1);
    let mut m: Vec<f32> = (0..n_total).map(|_| rng.normal()).collect();
    let mut v: Vec<f32> = (0..n_total).map(|_| rng.normal().abs()).collect();
    let mut p: Vec<f32> = (0..n_total).map(|_| rng.normal()).collect();
    let g: Vec<f32> = (0..n_total).map(|_| rng.normal()).collect();
    let hyper = Hyper { beta1: 0.9, beta2: 0.999, eps: 1e-8 };

    let pool_threads = lib.executor().threads();
    let mut record = |op: &str, chunk: usize, backend: &str, secs_per_call: f64| {
        results.push(obj(vec![
            ("op", op.into()),
            ("chunk", chunk.into()),
            ("backend", backend.into()),
            ("threads", pool_threads.into()),
            ("ns_per_elem", (secs_per_call * 1e9 / n_total as f64).into()),
            ("ms_per_call", (secs_per_call * 1e3).into()),
        ]));
    };

    for chunk in lib.manifest().chunk_sizes.clone() {
        let mut runner = ChunkRunner::new(lib.clone(), chunk).unwrap();

        let kt = bench(2, iters, || {
            runner.adama_acc(&mut m, &mut v, &g, 0.25).unwrap();
        });
        let ht = bench(2, iters, || {
            host_math::adama_acc(&mut m, &mut v, &g, 0.25, hyper.beta1, hyper.beta2);
        });
        record("adama_acc", chunk, "kernel", kt.mean());
        record("adama_acc", chunk, "host", ht.mean());
        println!(
            "{:<14} {:>10} {:>14.3} {:>14.3} {:>10.2}",
            "adama_acc",
            chunk,
            1e3 * kt.mean(),
            1e3 * ht.mean(),
            kt.mean() / ht.mean()
        );

        let ku = bench(2, iters, || {
            runner.adam_update(&mut p, &m, &v, 1e-3, 0.1, 0.001).unwrap();
        });
        let hu = bench(2, iters, || {
            host_math::adam_update(&mut p, &m, &v, 1e-3, 0.1, 0.001, hyper.eps);
        });
        record("adam_update", chunk, "kernel", ku.mean());
        record("adam_update", chunk, "host", hu.mean());
        println!(
            "{:<14} {:>10} {:>14.3} {:>14.3} {:>10.2}",
            "adam_update",
            chunk,
            1e3 * ku.mean(),
            1e3 * hu.mean(),
            ku.mean() / hu.mean()
        );
    }

    banner("model programs (tiny): per-call latency");
    let mut t =
        Trainer::new(lib.clone(), cfg("tiny", OptimizerKind::AdamA, 2, 42)).unwrap();
    let h = t.spec().hyper.clone();
    let mut corpus = MarkovCorpus::new(h.vocab, 7, 1);
    let mb = corpus.microbatch(h.microbatch, h.seq);
    {
        let (core, _) = t.parts_mut();
        let s = bench(2, iters, || {
            core.run_microbatch(&mb, &mut |_, _| Ok(())).unwrap();
        });
        println!(
            "microbatch fwd+bwd (no optimizer): {:.3} ms  (p50 {:.3}, p95 {:.3})",
            1e3 * s.mean(),
            1e3 * s.percentile(50.0),
            1e3 * s.percentile(95.0)
        );
        results.push(obj(vec![
            ("op", "microbatch_fwd_bwd_tiny".into()),
            ("backend", Json::Str(platform.clone())),
            ("threads", pool_threads.into()),
            ("ms_per_call", (s.mean() * 1e3).into()),
        ]));
    }

    banner("end-to-end train step (tiny, N=2): kernel vs host optimizer backend");
    for backend in [OptimBackend::Kernel, OptimBackend::Host] {
        let mut c = cfg("tiny", OptimizerKind::AdamA, 2, 42);
        c.backend = backend;
        let mut t = Trainer::new(lib.clone(), c).unwrap();
        let h = t.spec().hyper.clone();
        let mut corpus = MarkovCorpus::new(h.vocab, 7, 1);
        let mbs = corpus.minibatch(2, h.microbatch, h.seq);
        let s = bench(1, iters, || {
            t.train_step(&mbs).unwrap();
        });
        println!("{:?}: {:.2} ms/step", backend, 1e3 * s.mean());
        results.push(obj(vec![
            ("op", "train_step_tiny_n2".into()),
            (
                "backend",
                match backend {
                    OptimBackend::Kernel => "kernel",
                    OptimBackend::Host => "host",
                }
                .into(),
            ),
            ("threads", pool_threads.into()),
            ("ms_per_call", (s.mean() * 1e3).into()),
        ]));
    }

    banner("threadpool scaling: matmul + transformer block (1/2/4 threads)");
    println!("{:<18} {:>8} {:>12} {:>10}", "op", "threads", "ms/call", "speedup");
    let dim = if quick() { 96 } else { 256 };
    let env_lvl = simd::Level::from_env().expect("valid ADAMA_SIMD");
    let env_gm = GemmMode::from_env().expect("valid ADAMA_GEMM");
    let mut mrng = Rng::new(7);
    let ma: Vec<f32> = (0..dim * dim).map(|_| mrng.normal()).collect();
    let mb: Vec<f32> = (0..dim * dim).map(|_| mrng.normal()).collect();
    let mut mo = vec![0.0f32; dim * dim];
    let mut mpanel = Vec::new();
    let mut matmul_1t = 0.0f64;
    for threads in [1usize, 2, 4] {
        let pool = ThreadPool::new(threads);
        let s = bench(1, iters, || {
            math::matmul(&pool, env_lvl, env_gm, &mut mpanel, &ma, &mb, dim, dim, dim, &mut mo);
        });
        if threads == 1 {
            matmul_1t = s.mean();
        }
        let speedup = matmul_1t / s.mean();
        println!(
            "{:<18} {:>8} {:>12.3} {:>9.2}x",
            format!("matmul_{dim}"),
            threads,
            1e3 * s.mean(),
            speedup
        );
        results.push(obj(vec![
            ("op", Json::Str(format!("matmul_{dim}"))),
            ("backend", "host".into()),
            ("gemm", env_gm.name().into()),
            ("threads", threads.into()),
            ("ms_per_call", (s.mean() * 1e3).into()),
            ("speedup_vs_1thread", speedup.into()),
        ]));
    }
    // attention-dominated path: the `small` transformer block forward
    let mut arng = Rng::new(11);
    let mut block_1t = 0.0f64;
    for threads in [1usize, 2, 4] {
        let tlib = Library::host_with_threads(threads);
        let entry = tlib.entry("small/block_fwd").expect("small/block_fwd entry");
        let inputs: Vec<Value> = entry
            .inputs
            .iter()
            .map(|spec| {
                let data: Vec<f32> =
                    (0..spec.elements()).map(|_| 0.1 * arng.normal()).collect();
                Value::f32(data, &spec.shape).unwrap()
            })
            .collect();
        let prog = tlib.get("small/block_fwd").expect("small/block_fwd program");
        let s = bench(1, iters.min(5), || {
            prog.run_v(&inputs).unwrap();
        });
        if threads == 1 {
            block_1t = s.mean();
        }
        let speedup = block_1t / s.mean();
        println!(
            "{:<18} {:>8} {:>12.3} {:>9.2}x",
            "block_fwd_small", threads, 1e3 * s.mean(), speedup
        );
        results.push(obj(vec![
            ("op", "block_fwd_small".into()),
            ("backend", "host".into()),
            ("threads", threads.into()),
            ("ms_per_call", (s.mean() * 1e3).into()),
            ("speedup_vs_1thread", speedup.into()),
        ]));
    }

    banner("GEMM engines: packed (cache-blocked) vs naive, GFLOP/s per shape");
    println!(
        "{:<16} {:>14} {:>8} {:>11} {:>11} {:>9} {:>9}",
        "shape", "m x k x n", "threads", "naive ms", "packed ms", "GFLOP/s", "speedup"
    );
    let mut gemm_regressions: Vec<String> = Vec::new();
    {
        // square (cache-blocking headroom), transformer-shaped skinny/fat
        // ([b·s,h]·[h,3h] and [b·s,h]·[h,f]), and a remainder-heavy odd
        // shape that exercises every partial tile/block edge
        let sq = if quick() { 256 } else { 512 };
        let gemm_shapes: [(&str, usize, usize, usize); 4] = [
            ("square", sq, sq, sq),
            ("qkv_skinny", 1024, 192, 576),
            ("ffn_fat", 512, 256, 1024),
            ("odd_remainder", 129, 67, 193),
        ];
        let gpool = ThreadPool::new(pool_threads);
        let mut grng = Rng::new(29);
        for (shape, m, k, n) in gemm_shapes {
            let ga: Vec<f32> = (0..m * k).map(|_| grng.normal()).collect();
            let gb: Vec<f32> = (0..k * n).map(|_| grng.normal()).collect();
            let mut gout = vec![0.0f32; m * n];
            let mut panel = Vec::new();
            let flops = 2.0 * (m * k * n) as f64;
            let tn = bench(1, iters.min(8), || {
                let p = &mut panel;
                math::matmul(&gpool, env_lvl, GemmMode::Naive, p, &ga, &gb, m, k, n, &mut gout);
            });
            let tp = bench(1, iters.min(8), || {
                let p = &mut panel;
                math::matmul(&gpool, env_lvl, GemmMode::Packed, p, &ga, &gb, m, k, n, &mut gout);
            });
            let speedup = tn.mean() / tp.mean();
            println!(
                "{:<16} {:>14} {:>8} {:>11.3} {:>11.3} {:>9.2} {:>8.2}x",
                shape,
                format!("{m}x{k}x{n}"),
                pool_threads,
                1e3 * tn.mean(),
                1e3 * tp.mean(),
                flops / tp.mean() / 1e9,
                speedup
            );
            for (gm, t) in [(GemmMode::Naive, &tn), (GemmMode::Packed, &tp)] {
                let mut row = vec![
                    ("op", Json::Str(format!("gemm_{shape}"))),
                    ("backend", "host".into()),
                    ("gemm", gm.name().into()),
                    ("threads", pool_threads.into()),
                    ("m", m.into()),
                    ("k", k.into()),
                    ("n", n.into()),
                    ("ms_per_call", (t.mean() * 1e3).into()),
                    ("gflops", (flops / t.mean() / 1e9).into()),
                ];
                if gm == GemmMode::Packed {
                    row.push(("speedup_packed_vs_naive", speedup.into()));
                }
                results.push(obj(row));
            }
            if speedup < 0.9 {
                gemm_regressions.push(format!(
                    "gemm_{shape} ({m}x{k}x{n}): packed {:.3} ms vs naive {:.3} ms",
                    1e3 * tp.mean(),
                    1e3 * tn.mean()
                ));
            }
        }
    }
    println!("(engines verified bit-identical in rust/tests/proptests.rs and simd_parity.rs)");

    banner("SIMD dispatch: optimizer kernels + `small` block fwd/bwd, scalar vs vector");
    let detected = simd::detect();
    println!("detected level: {} (ADAMA_SIMD resolves to {})", detected.name(), env_lvl.name());
    println!(
        "{:<18} {:>8} {:>12} {:>12} {:>9}",
        "op", "lanes", "scalar ms", "simd ms", "speedup"
    );
    {
        let mut srng = Rng::new(17);
        let mut sm: Vec<f32> = (0..n_total).map(|_| srng.normal()).collect();
        let mut sv: Vec<f32> = (0..n_total).map(|_| srng.normal().abs()).collect();
        let mut sp: Vec<f32> = (0..n_total).map(|_| srng.normal()).collect();
        let sg: Vec<f32> = (0..n_total).map(|_| srng.normal()).collect();
        let (b1, b2, eps) = (hyper.beta1, hyper.beta2, hyper.eps);
        let (res, reg) = (&mut results, &mut simd_regressions);
        simd_row(res, reg, "adama_acc", iters, n_total, detected, &mut |l| {
            simd::adama_acc(l, &mut sm, &mut sv, &sg, 0.25, b1, b2);
        });
        simd_row(res, reg, "adama_decay_acc", iters, n_total, detected, &mut |l| {
            simd::adama_decay_acc(l, &mut sm, &mut sv, &sg, 0.25, b1, b2, b1, b2);
        });
        simd_row(res, reg, "adam_update", iters, n_total, detected, &mut |l| {
            simd::adam_update(l, &mut sp, &sm, &sv, 1e-3, 0.1, 0.001, eps);
        });
        simd_row(res, reg, "adam_full", iters, n_total, detected, &mut |l| {
            simd::adam_full(l, &mut sp, &mut sm, &mut sv, &sg, 1e-3, 0.1, 0.001, b1, b2, eps);
        });
        simd_row(res, reg, "adamw_update", iters, n_total, detected, &mut |l| {
            simd::adamw_update(l, &mut sp, &sm, &sv, 1e-3, 0.1, 0.001, 0.01, eps);
        });
        simd_row(res, reg, "grad_acc", iters, n_total, detected, &mut |l| {
            simd::grad_acc(l, &mut sp, &sg, 0.25);
        });
        simd_row(res, reg, "sgdm_decay_acc", iters, n_total, detected, &mut |l| {
            simd::sgdm_decay_acc(l, &mut sm, &sg, 0.5, 0.9);
        });
        simd_row(res, reg, "sgdm_acc", iters, n_total, detected, &mut |l| {
            simd::sgdm_acc(l, &mut sm, &sg, 0.5);
        });
        simd_row(res, reg, "sgdm_update", iters, n_total, detected, &mut |l| {
            simd::sgdm_update(l, &mut sp, &sm, 1e-2, 0.01);
        });
        simd_row(res, reg, "scale", iters, n_total, detected, &mut |l| {
            simd::scale(l, &mut sv, 0.999);
        });
    }
    // `small` block forward/backward at scalar vs vector dispatch
    let block_levels = if detected == simd::Level::Scalar {
        vec![simd::Level::Scalar]
    } else {
        vec![simd::Level::Scalar, detected]
    };
    let mut scalar_block = [0.0f64; 2]; // [fwd, bwd]
    for level in block_levels {
        let tlib = Library::host_with_simd(1, MemoryPlan::remat(), level);
        let entry = tlib.entry("small/block_fwd").expect("small/block_fwd entry");
        let mut arng = Rng::new(23);
        let fwd_inputs: Vec<Value> = entry
            .inputs
            .iter()
            .map(|spec| {
                let data: Vec<f32> =
                    (0..spec.elements()).map(|_| 0.1 * arng.normal()).collect();
                Value::f32(data, &spec.shape).unwrap()
            })
            .collect();
        let x_spec = &entry.inputs[0];
        let dy: Vec<f32> = (0..x_spec.elements()).map(|_| 0.1 * arng.normal()).collect();
        let mut bwd_inputs: Vec<Value> =
            vec![fwd_inputs[0].clone(), Value::f32(dy, &x_spec.shape).unwrap()];
        bwd_inputs.extend(fwd_inputs[1..].iter().cloned());
        let fwd = tlib.get("small/block_fwd").expect("small/block_fwd program");
        let bwd = tlib.get("small/block_bwd").expect("small/block_bwd program");
        let cases = [
            ("block_fwd_small", &fwd, &fwd_inputs),
            ("block_bwd_small", &bwd, &bwd_inputs),
        ];
        for (idx, (op, prog, inputs)) in cases.into_iter().enumerate() {
            let s = bench(1, iters.min(5), || {
                prog.run_v(inputs).unwrap();
            });
            let speedup = if level == simd::Level::Scalar {
                scalar_block[idx] = s.mean();
                1.0
            } else {
                scalar_block[idx] / s.mean()
            };
            println!(
                "{:<18} {:>8} {:>12} {:>12.3} {:>8.2}x",
                op,
                level.name(),
                "-",
                1e3 * s.mean(),
                speedup
            );
            results.push(obj(vec![
                ("op", Json::Str(format!("{op}_simd"))),
                ("backend", "host".into()),
                ("simd", level.name().into()),
                ("threads", 1usize.into()),
                ("ms_per_call", (s.mean() * 1e3).into()),
                ("speedup_vs_scalar", speedup.into()),
            ]));
            if level != simd::Level::Scalar && speedup < 0.9 {
                simd_regressions.push(format!(
                    "{op}: {} {:.3} ms vs scalar {:.3} ms",
                    level.name(),
                    1e3 * s.mean(),
                    1e3 * scalar_block[idx]
                ));
            }
        }
    }

    banner("activation stash vs remat: `small` block fwd+bwd pair (ADAMA_ACT_BUDGET)");
    println!(
        "{:<10} {:>8} {:>12} {:>10} {:>8} {:>8}",
        "budget", "threads", "ms/pair", "vs remat", "hits", "remats"
    );
    for threads in [1usize, 4] {
        let mut remat_pair_ms = 0.0f64;
        for (mode, plan) in
            [("0", MemoryPlan::remat()), ("unlimited", MemoryPlan::unlimited())]
        {
            let tlib = Library::host_with_plan(threads, plan);
            let entry = tlib.entry("small/block_fwd").expect("small/block_fwd entry");
            let mut arng = Rng::new(13);
            // fwd inputs: (x, *12 params); bwd reuses the SAME x and
            // params (a stash hit requires a bit-identical input)
            let fwd_inputs: Vec<Value> = entry
                .inputs
                .iter()
                .map(|spec| {
                    let data: Vec<f32> =
                        (0..spec.elements()).map(|_| 0.1 * arng.normal()).collect();
                    Value::f32(data, &spec.shape).unwrap()
                })
                .collect();
            let x_spec = &entry.inputs[0];
            let dy: Vec<f32> =
                (0..x_spec.elements()).map(|_| 0.1 * arng.normal()).collect();
            let mut bwd_inputs: Vec<Value> = vec![
                fwd_inputs[0].clone(),
                Value::f32(dy, &x_spec.shape).unwrap(),
            ];
            bwd_inputs.extend(fwd_inputs[1..].iter().cloned());

            let fwd = tlib.get("small/block_fwd").expect("small/block_fwd program");
            let bwd = tlib.get("small/block_bwd").expect("small/block_bwd program");
            let s = bench(1, iters.min(5), || {
                fwd.run_v(&fwd_inputs).unwrap();
                bwd.run_v(&bwd_inputs).unwrap();
            });
            if mode == "0" {
                remat_pair_ms = s.mean();
            }
            let speedup = remat_pair_ms / s.mean();
            let mem = tlib.executor().memory().unwrap_or_default();
            println!(
                "{:<10} {:>8} {:>12.3} {:>9.2}x {:>8} {:>8}",
                mode,
                threads,
                1e3 * s.mean(),
                speedup,
                mem.stash_hits,
                mem.remats
            );
            results.push(obj(vec![
                ("op", "block_bwd_stash_vs_remat_small".into()),
                ("backend", "host".into()),
                ("act_budget", mode.into()),
                ("threads", threads.into()),
                ("ms_per_fwd_bwd_pair", (s.mean() * 1e3).into()),
                ("speedup_vs_remat", speedup.into()),
                ("stash_hits", (mem.stash_hits as usize).into()),
                ("remats", (mem.remats as usize).into()),
            ]));
        }
    }
    println!("(the stashed backward skips the in-call forward recompute entirely)");

    banner("distributed: concurrent fabric vs serial simulator (per rank count)");
    println!(
        "{:<24} {:>6} {:>12} {:>12} {:>8}",
        "flow", "ranks", "serial ms", "fabric ms", "speedup"
    );
    let dsteps: u64 = if quick() { 1 } else { 2 };
    for m in [1usize, 2, 4] {
        let mut dcfg = cfg("tiny", OptimizerKind::AdamA, 2, 42);
        dcfg.workers = m;
        let time_dp = |engine: CollectiveEngine| {
            let t0 = std::time::Instant::now();
            run_data_parallel(
                lib.clone(),
                DpSpec::new(dcfg.clone(), SyncStrategy::OptimizerStates, dsteps, 7)
                    .with_engine(engine),
            )
            .expect("dp run");
            1e3 * t0.elapsed().as_secs_f64() / dsteps as f64
        };
        let serial_ms = time_dp(CollectiveEngine::Serial);
        let fabric_ms = time_dp(CollectiveEngine::Fabric);
        println!(
            "{:<24} {:>6} {:>12.2} {:>12.2} {:>7.2}x",
            "dp_state_allreduce", m, serial_ms, fabric_ms, serial_ms / fabric_ms
        );
        results.push(obj(vec![
            ("op", "dp_fabric_vs_serial".into()),
            ("backend", "host".into()),
            ("ranks", m.into()),
            ("threads", pool_threads.into()),
            ("serial_ms_per_step", serial_ms.into()),
            ("fabric_ms_per_step", fabric_ms.into()),
            ("speedup_fabric_vs_serial", (serial_ms / fabric_ms).into()),
        ]));
    }
    {
        // ZeRO-S1+AdamA: the per-layer release-immediately reduce-scatter
        // (paper's backward/reduce overlap) under both engines
        let mut zcfg = cfg("tiny", OptimizerKind::AdamA, 2, 42);
        zcfg.workers = 2;
        let time_zero = |engine: CollectiveEngine| {
            let t0 = std::time::Instant::now();
            run_zero1(
                lib.clone(),
                Zero1Spec::new(zcfg.clone(), dsteps, 7).with_engine(engine),
            )
            .expect("zero1 run");
            1e3 * t0.elapsed().as_secs_f64() / dsteps as f64
        };
        let serial_ms = time_zero(CollectiveEngine::Serial);
        let fabric_ms = time_zero(CollectiveEngine::Fabric);
        println!(
            "{:<24} {:>6} {:>12.2} {:>12.2} {:>7.2}x",
            "zero1_adama_overlap", 2, serial_ms, fabric_ms, serial_ms / fabric_ms
        );
        results.push(obj(vec![
            ("op", "zero1_fabric_vs_serial".into()),
            ("backend", "host".into()),
            ("ranks", 2usize.into()),
            ("threads", pool_threads.into()),
            ("serial_ms_per_step", serial_ms.into()),
            ("fabric_ms_per_step", fabric_ms.into()),
            ("speedup_fabric_vs_serial", (serial_ms / fabric_ms).into()),
        ]));
    }
    let mut async_regressions: Vec<String> = Vec::new();
    {
        // async issue: the same ZeRO-S1+AdamA flow with per-layer
        // reductions handed to the comm thread (ADAMA_ASYNC=1 semantics),
        // so layer k's reduce-scatter overlaps layer k-1's backward —
        // vs the blocking issue above. Bit-identical by construction
        // (rust/tests/fabric_parity.rs); the row measures pure overlap.
        println!();
        println!(
            "{:<24} {:>6} {:>12} {:>12} {:>8}",
            "flow", "ranks", "sync ms", "async ms", "speedup"
        );
        for m in [2usize, 4] {
            let mut acfg = cfg("tiny", OptimizerKind::AdamA, 2, 42);
            acfg.workers = m;
            let time_zero = |async_issue: bool| {
                let t0 = std::time::Instant::now();
                run_zero1(
                    lib.clone(),
                    Zero1Spec::new(acfg.clone(), dsteps, 7)
                        .with_engine(CollectiveEngine::Fabric)
                        .with_async(async_issue)
                        .with_bucket_bytes(0),
                )
                .expect("zero1 async run");
                1e3 * t0.elapsed().as_secs_f64() / dsteps as f64
            };
            let sync_ms = time_zero(false);
            let async_ms = time_zero(true);
            let speedup = sync_ms / async_ms;
            println!(
                "{:<24} {:>6} {:>12.2} {:>12.2} {:>7.2}x",
                "zero1_async_issue", m, sync_ms, async_ms, speedup
            );
            results.push(obj(vec![
                ("op", "zero1_async_vs_sync".into()),
                ("backend", "host".into()),
                ("ranks", m.into()),
                ("threads", pool_threads.into()),
                ("sync_ms_per_step", sync_ms.into()),
                ("async_ms_per_step", async_ms.into()),
                ("speedup_async_vs_sync", speedup.into()),
            ]));
            if speedup < 0.9 {
                async_regressions.push(format!(
                    "zero1_async_vs_sync (M={m}): async {async_ms:.2} ms vs sync {sync_ms:.2} ms"
                ));
            }
        }
    }
    println!("(engines verified bit-identical in rust/tests/fabric_parity.rs)");

    banner("checkpoint: ADAMACK2 container save/load throughput (atomic tmp+rename)");
    println!("{:<18} {:>12} {:>12} {:>12}", "op", "bytes", "ms/call", "MB/s");
    {
        let ccfg = cfg("tiny", OptimizerKind::AdamA, 2, 42);
        let mut ct = Trainer::new(lib.clone(), ccfg).unwrap();
        let ch = ct.spec().hyper.clone();
        let mut ccorpus = MarkovCorpus::new(ch.vocab, 7, 1);
        let cmbs = ccorpus.minibatch(2, ch.microbatch, ch.seq);
        ct.train_step(&cmbs).unwrap();
        let cdir = std::env::temp_dir().join(format!("adama_bench_ckpt_{}", std::process::id()));
        std::fs::create_dir_all(&cdir).expect("bench checkpoint dir");
        let cpath = cdir.join("bench.ck2");
        let state = ct.train_state(&[ccorpus.rng().clone()]).unwrap();
        let st = bench(1, iters.min(8), || {
            state.save(&cpath).unwrap();
        });
        let bytes = std::fs::metadata(&cpath).expect("bench checkpoint file").len() as usize;
        let sl = bench(1, iters.min(8), || {
            TrainState::load(&cpath).unwrap();
        });
        for (op, s) in [("checkpoint_save_ck2", &st), ("checkpoint_load_ck2", &sl)] {
            let mbps = bytes as f64 / 1e6 / s.mean();
            println!("{:<18} {:>12} {:>12.3} {:>12.1}", op, bytes, 1e3 * s.mean(), mbps);
            results.push(obj(vec![
                ("op", op.into()),
                ("backend", "host".into()),
                ("threads", pool_threads.into()),
                ("bytes", bytes.into()),
                ("ms_per_call", (s.mean() * 1e3).into()),
                ("mb_per_s", mbps.into()),
            ]));
        }
        let _ = std::fs::remove_dir_all(&cdir);
    }
    println!("(save is serialize + per-section FNV hash + tmp write + rename; load re-verifies)");

    banner("serving: batched KV-cache decode over the scheduler (tiny)");
    println!(
        "{:<24} {:>6} {:>10} {:>10} {:>10} {:>9}",
        "op", "batch", "tok/s", "p50 ms", "p99 ms", "prefills"
    );
    let mut serve_regressions: Vec<String> = Vec::new();
    {
        let sload = SyntheticLoad {
            requests: if quick() { 4 } else { 8 },
            prompt_len: 8,
            max_new: if quick() { 4 } else { 8 },
            arrive_every: 1,
            seed: 9,
        };
        let slib = Library::host_with_threads(pool_threads);
        let mut tps_serial = 0.0f64;
        for max_batch in [1usize, 4] {
            let engine =
                InferenceEngine::init_random(slib.clone(), "tiny", 42).expect("serve engine");
            let mut sched = Scheduler::with_budget(engine, max_batch, None);
            let stats = sload.run(&mut sched).expect("synthetic load");
            let tps = stats.tokens_per_sec();
            if max_batch == 1 {
                tps_serial = tps;
            }
            println!(
                "{:<24} {:>6} {:>10.0} {:>10.2} {:>10.2} {:>9}",
                "serve_decode",
                max_batch,
                tps,
                1e3 * stats.p50(),
                1e3 * stats.p99(),
                sload.requests
            );
            results.push(obj(vec![
                ("op", "serve_decode".into()),
                ("backend", "host".into()),
                ("threads", pool_threads.into()),
                ("max_batch", max_batch.into()),
                ("requests", sload.requests.into()),
                ("tokens_per_sec", tps.into()),
                ("latency_p50_ms", (1e3 * stats.p50()).into()),
                ("latency_p99_ms", (1e3 * stats.p99()).into()),
                ("decode_steps", (sched.steps() as usize).into()),
            ]));
            if max_batch > 1 && tps < 0.9 * tps_serial {
                serve_regressions.push(format!(
                    "serve_decode: batch={max_batch} {tps:.0} tok/s vs serial {tps_serial:.0} tok/s"
                ));
            }
        }
        // eviction under a tight KV cap: each request peaks at
        // prompt+max_new-1 cached tokens; a cap of ~1.5 peaks forces the
        // scheduler to evict and re-prefill — same tokens, extra work.
        let engine =
            InferenceEngine::init_random(slib.clone(), "tiny", 42).expect("serve engine");
        let peak = (sload.prompt_len + sload.max_new - 1) as u64;
        let cap = (peak + peak / 2) * engine.kv_bytes_per_token();
        let prompts = sload.prompts(engine.hyper().vocab);
        let mut sched = Scheduler::with_budget(engine, 4, Some(cap));
        let t0 = std::time::Instant::now();
        for p in &prompts {
            sched.submit(p, sload.max_new).expect("submit under cap");
        }
        let done = sched.run_to_completion(100_000).expect("drain under cap");
        let mut stats = ServeStats::new();
        for c in &done {
            stats.record(c.latency_s, c.tokens.len() as u64);
        }
        stats.set_wall_seconds(t0.elapsed().as_secs_f64());
        let prefills: u32 = done.iter().map(|c| c.prefills).sum();
        println!(
            "{:<24} {:>6} {:>10.0} {:>10.2} {:>10.2} {:>9}",
            "serve_decode_kv_budget",
            4,
            stats.tokens_per_sec(),
            1e3 * stats.p50(),
            1e3 * stats.p99(),
            prefills
        );
        results.push(obj(vec![
            ("op", "serve_decode_kv_budget".into()),
            ("backend", "host".into()),
            ("threads", pool_threads.into()),
            ("max_batch", 4usize.into()),
            ("requests", sload.requests.into()),
            ("kv_budget_bytes", (cap as usize).into()),
            ("tokens_per_sec", stats.tokens_per_sec().into()),
            ("latency_p50_ms", (1e3 * stats.p50()).into()),
            ("latency_p99_ms", (1e3 * stats.p99()).into()),
            ("prefills_total", (prefills as usize).into()),
        ]));
    }
    println!("(decode is bit-identical to the full-context forward: rust/tests/serve.rs)");

    banner("executor call count (instrumentation)");
    println!("exec calls so far: {}", lib.executor().exec_calls());

    let report = obj(vec![
        ("platform", Json::Str(platform)),
        ("elements", n_total.into()),
        ("iters", iters.into()),
        ("results", Json::Arr(results)),
    ]);
    let path = "BENCH_perf.json";
    match std::fs::write(path, report.to_string_pretty()) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }

    // hard gates: the SIMD path must never run slower than scalar, the
    // packed GEMM engine must never run slower than the naive loops,
    // async issue must never run slower than blocking issue, and batched
    // serving must never run slower than serial serving (each with a
    // noise allowance) — a regression fails the bench run.
    // Only armed at the full iteration count: 3-iteration --quick samples
    // on shared CI are too jittery to turn into a red build.
    let mut gated = false;
    if !simd_regressions.is_empty() {
        eprintln!("\nSIMD regression vs scalar:");
        for r in &simd_regressions {
            eprintln!("  {r}");
        }
        gated = true;
    }
    if !gemm_regressions.is_empty() {
        eprintln!("\npacked GEMM regression vs naive:");
        for r in &gemm_regressions {
            eprintln!("  {r}");
        }
        gated = true;
    }
    if !async_regressions.is_empty() {
        eprintln!("\nasync-issue regression vs blocking issue:");
        for r in &async_regressions {
            eprintln!("  {r}");
        }
        gated = true;
    }
    if !serve_regressions.is_empty() {
        eprintln!("\nbatched serving regression vs serial serving:");
        for r in &serve_regressions {
            eprintln!("  {r}");
        }
        gated = true;
    }
    if gated {
        if quick() {
            eprintln!("(--quick run: regression gate not armed, rows recorded only)");
        } else {
            std::process::exit(1);
        }
    }
}

/// Bench one SIMD kernel at `Level::Scalar` vs the detected dispatch
/// level, record both rows, and note a regression when the vector path
/// is slower than scalar beyond a 10% noise allowance.
#[allow(clippy::too_many_arguments)]
fn simd_row(
    results: &mut Vec<Json>,
    regressions: &mut Vec<String>,
    op: &str,
    iters: usize,
    n_total: usize,
    detected: simd::Level,
    f: &mut dyn FnMut(simd::Level),
) {
    let ts = bench(2, iters, || f(simd::Level::Scalar));
    results.push(obj(vec![
        ("op", Json::Str(format!("simd_{op}"))),
        ("backend", "simd".into()),
        ("simd", "scalar".into()),
        ("threads", 1usize.into()),
        ("ns_per_elem", (ts.mean() * 1e9 / n_total as f64).into()),
        ("ms_per_call", (ts.mean() * 1e3).into()),
    ]));
    if detected == simd::Level::Scalar {
        println!("{:<18} {:>8} {:>12.3} {:>12} {:>9}", op, "-", 1e3 * ts.mean(), "-", "-");
        return;
    }
    let tv = bench(2, iters, || f(detected));
    let speedup = ts.mean() / tv.mean();
    results.push(obj(vec![
        ("op", Json::Str(format!("simd_{op}"))),
        ("backend", "simd".into()),
        ("simd", detected.name().into()),
        ("threads", 1usize.into()),
        ("ns_per_elem", (tv.mean() * 1e9 / n_total as f64).into()),
        ("ms_per_call", (tv.mean() * 1e3).into()),
        ("speedup_vs_scalar", speedup.into()),
    ]));
    println!(
        "{:<18} {:>8} {:>12.3} {:>12.3} {:>8.2}x",
        op,
        detected.name(),
        1e3 * ts.mean(),
        1e3 * tv.mean(),
        speedup
    );
    if speedup < 0.9 {
        regressions.push(format!(
            "{op}: {} {:.3} ms vs scalar {:.3} ms",
            detected.name(),
            1e3 * tv.mean(),
            1e3 * ts.mean()
        ));
    }
}
