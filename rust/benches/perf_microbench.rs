//! Perf microbenchmarks — the §Perf instrument (EXPERIMENTS.md).
//!
//! Times the building blocks of the hot path in isolation:
//!   * chunked optimizer kernels (program dispatch) vs raw host loops,
//!     per chunk size;
//!   * a micro-batch forward+backward over the model programs;
//!   * a full tiny train step (end-to-end floor);
//!   * thread-pool scaling: matmul and the `small` transformer block
//!     forward at 1/2/4 pool threads (per-thread-count rows, so the
//!     speedup is machine-recorded in the trajectory);
//!   * activation stash vs remat: the `small` block forward+backward
//!     pair at budget 0 (per-layer remat) vs unlimited (stash hit —
//!     backward skips the recompute), at 1 and 4 threads.
//!
//! Besides the human-readable table, writes `BENCH_perf.json` —
//! machine-readable ns/elem per kernel per backend (each row tagged with
//! its pool thread count) — so subsequent PRs have a perf trajectory to
//! regress against.

use adama::config::{OptimBackend, OptimizerKind};
use adama::data::MarkovCorpus;
use adama::optim::{host_math, ChunkRunner, Hyper};
use adama::runtime::hostexec::math;
use adama::runtime::{Library, MemoryPlan, ThreadPool, Value};
use adama::tensor::Rng;
use adama::util::json::{obj, Json};
use adama::util::stats::bench;
use adama::Trainer;

#[path = "support/mod.rs"]
mod support;
use support::{banner, cfg, lib_or_exit, quick};

fn main() {
    let lib = lib_or_exit();
    let iters = if quick() { 3 } else { 20 };
    let platform = lib.executor().platform();
    let mut results: Vec<Json> = Vec::new();

    banner("optimizer kernels: chunked program dispatch vs raw host loop (1M elements)");
    println!(
        "{:<14} {:>10} {:>14} {:>14} {:>10}",
        "op", "chunk", "kernel (ms)", "host (ms)", "k/h"
    );
    let n_total: usize = 1 << 20;
    let mut rng = Rng::new(1);
    let mut m: Vec<f32> = (0..n_total).map(|_| rng.normal()).collect();
    let mut v: Vec<f32> = (0..n_total).map(|_| rng.normal().abs()).collect();
    let mut p: Vec<f32> = (0..n_total).map(|_| rng.normal()).collect();
    let g: Vec<f32> = (0..n_total).map(|_| rng.normal()).collect();
    let hyper = Hyper { beta1: 0.9, beta2: 0.999, eps: 1e-8 };

    let pool_threads = lib.executor().threads();
    let mut record = |op: &str, chunk: usize, backend: &str, secs_per_call: f64| {
        results.push(obj(vec![
            ("op", op.into()),
            ("chunk", chunk.into()),
            ("backend", backend.into()),
            ("threads", pool_threads.into()),
            ("ns_per_elem", (secs_per_call * 1e9 / n_total as f64).into()),
            ("ms_per_call", (secs_per_call * 1e3).into()),
        ]));
    };

    for chunk in lib.manifest().chunk_sizes.clone() {
        let mut runner = ChunkRunner::new(lib.clone(), chunk).unwrap();

        let kt = bench(2, iters, || {
            runner.adama_acc(&mut m, &mut v, &g, 0.25).unwrap();
        });
        let ht = bench(2, iters, || {
            host_math::adama_acc(&mut m, &mut v, &g, 0.25, hyper.beta1, hyper.beta2);
        });
        record("adama_acc", chunk, "kernel", kt.mean());
        record("adama_acc", chunk, "host", ht.mean());
        println!(
            "{:<14} {:>10} {:>14.3} {:>14.3} {:>10.2}",
            "adama_acc",
            chunk,
            1e3 * kt.mean(),
            1e3 * ht.mean(),
            kt.mean() / ht.mean()
        );

        let ku = bench(2, iters, || {
            runner.adam_update(&mut p, &m, &v, 1e-3, 0.1, 0.001).unwrap();
        });
        let hu = bench(2, iters, || {
            host_math::adam_update(&mut p, &m, &v, 1e-3, 0.1, 0.001, hyper.eps);
        });
        record("adam_update", chunk, "kernel", ku.mean());
        record("adam_update", chunk, "host", hu.mean());
        println!(
            "{:<14} {:>10} {:>14.3} {:>14.3} {:>10.2}",
            "adam_update",
            chunk,
            1e3 * ku.mean(),
            1e3 * hu.mean(),
            ku.mean() / hu.mean()
        );
    }

    banner("model programs (tiny): per-call latency");
    let mut t =
        Trainer::new(lib.clone(), cfg("tiny", OptimizerKind::AdamA, 2, 42)).unwrap();
    let h = t.spec().hyper.clone();
    let mut corpus = MarkovCorpus::new(h.vocab, 7, 1);
    let mb = corpus.microbatch(h.microbatch, h.seq);
    {
        let (core, _) = t.parts_mut();
        let s = bench(2, iters, || {
            core.run_microbatch(&mb, &mut |_, _| Ok(())).unwrap();
        });
        println!(
            "microbatch fwd+bwd (no optimizer): {:.3} ms  (p50 {:.3}, p95 {:.3})",
            1e3 * s.mean(),
            1e3 * s.percentile(50.0),
            1e3 * s.percentile(95.0)
        );
        results.push(obj(vec![
            ("op", "microbatch_fwd_bwd_tiny".into()),
            ("backend", Json::Str(platform.clone())),
            ("threads", pool_threads.into()),
            ("ms_per_call", (s.mean() * 1e3).into()),
        ]));
    }

    banner("end-to-end train step (tiny, N=2): kernel vs host optimizer backend");
    for backend in [OptimBackend::Kernel, OptimBackend::Host] {
        let mut c = cfg("tiny", OptimizerKind::AdamA, 2, 42);
        c.backend = backend;
        let mut t = Trainer::new(lib.clone(), c).unwrap();
        let h = t.spec().hyper.clone();
        let mut corpus = MarkovCorpus::new(h.vocab, 7, 1);
        let mbs = corpus.minibatch(2, h.microbatch, h.seq);
        let s = bench(1, iters, || {
            t.train_step(&mbs).unwrap();
        });
        println!("{:?}: {:.2} ms/step", backend, 1e3 * s.mean());
        results.push(obj(vec![
            ("op", "train_step_tiny_n2".into()),
            (
                "backend",
                match backend {
                    OptimBackend::Kernel => "kernel",
                    OptimBackend::Host => "host",
                }
                .into(),
            ),
            ("threads", pool_threads.into()),
            ("ms_per_call", (s.mean() * 1e3).into()),
        ]));
    }

    banner("threadpool scaling: matmul + transformer block (1/2/4 threads)");
    println!("{:<18} {:>8} {:>12} {:>10}", "op", "threads", "ms/call", "speedup");
    let dim = if quick() { 96 } else { 256 };
    let mut mrng = Rng::new(7);
    let ma: Vec<f32> = (0..dim * dim).map(|_| mrng.normal()).collect();
    let mb: Vec<f32> = (0..dim * dim).map(|_| mrng.normal()).collect();
    let mut mo = vec![0.0f32; dim * dim];
    let mut matmul_1t = 0.0f64;
    for threads in [1usize, 2, 4] {
        let pool = ThreadPool::new(threads);
        let s = bench(1, iters, || {
            math::matmul(&pool, &ma, &mb, dim, dim, dim, &mut mo);
        });
        if threads == 1 {
            matmul_1t = s.mean();
        }
        let speedup = matmul_1t / s.mean();
        println!(
            "{:<18} {:>8} {:>12.3} {:>9.2}x",
            format!("matmul_{dim}"),
            threads,
            1e3 * s.mean(),
            speedup
        );
        results.push(obj(vec![
            ("op", Json::Str(format!("matmul_{dim}"))),
            ("backend", "host".into()),
            ("threads", threads.into()),
            ("ms_per_call", (s.mean() * 1e3).into()),
            ("speedup_vs_1thread", speedup.into()),
        ]));
    }
    // attention-dominated path: the `small` transformer block forward
    let mut arng = Rng::new(11);
    let mut block_1t = 0.0f64;
    for threads in [1usize, 2, 4] {
        let tlib = Library::host_with_threads(threads);
        let entry = tlib.entry("small/block_fwd").expect("small/block_fwd entry");
        let inputs: Vec<Value> = entry
            .inputs
            .iter()
            .map(|spec| {
                let data: Vec<f32> =
                    (0..spec.elements()).map(|_| 0.1 * arng.normal()).collect();
                Value::f32(data, &spec.shape).unwrap()
            })
            .collect();
        let prog = tlib.get("small/block_fwd").expect("small/block_fwd program");
        let s = bench(1, iters.min(5), || {
            prog.run_v(&inputs).unwrap();
        });
        if threads == 1 {
            block_1t = s.mean();
        }
        let speedup = block_1t / s.mean();
        println!(
            "{:<18} {:>8} {:>12.3} {:>9.2}x",
            "block_fwd_small", threads, 1e3 * s.mean(), speedup
        );
        results.push(obj(vec![
            ("op", "block_fwd_small".into()),
            ("backend", "host".into()),
            ("threads", threads.into()),
            ("ms_per_call", (s.mean() * 1e3).into()),
            ("speedup_vs_1thread", speedup.into()),
        ]));
    }

    banner("activation stash vs remat: `small` block fwd+bwd pair (ADAMA_ACT_BUDGET)");
    println!(
        "{:<10} {:>8} {:>12} {:>10} {:>8} {:>8}",
        "budget", "threads", "ms/pair", "vs remat", "hits", "remats"
    );
    for threads in [1usize, 4] {
        let mut remat_pair_ms = 0.0f64;
        for (mode, plan) in
            [("0", MemoryPlan::remat()), ("unlimited", MemoryPlan::unlimited())]
        {
            let tlib = Library::host_with_plan(threads, plan);
            let entry = tlib.entry("small/block_fwd").expect("small/block_fwd entry");
            let mut arng = Rng::new(13);
            // fwd inputs: (x, *12 params); bwd reuses the SAME x and
            // params (a stash hit requires a bit-identical input)
            let fwd_inputs: Vec<Value> = entry
                .inputs
                .iter()
                .map(|spec| {
                    let data: Vec<f32> =
                        (0..spec.elements()).map(|_| 0.1 * arng.normal()).collect();
                    Value::f32(data, &spec.shape).unwrap()
                })
                .collect();
            let x_spec = &entry.inputs[0];
            let dy: Vec<f32> =
                (0..x_spec.elements()).map(|_| 0.1 * arng.normal()).collect();
            let mut bwd_inputs: Vec<Value> = vec![
                fwd_inputs[0].clone(),
                Value::f32(dy, &x_spec.shape).unwrap(),
            ];
            bwd_inputs.extend(fwd_inputs[1..].iter().cloned());

            let fwd = tlib.get("small/block_fwd").expect("small/block_fwd program");
            let bwd = tlib.get("small/block_bwd").expect("small/block_bwd program");
            let s = bench(1, iters.min(5), || {
                fwd.run_v(&fwd_inputs).unwrap();
                bwd.run_v(&bwd_inputs).unwrap();
            });
            if mode == "0" {
                remat_pair_ms = s.mean();
            }
            let speedup = remat_pair_ms / s.mean();
            let mem = tlib.executor().memory().unwrap_or_default();
            println!(
                "{:<10} {:>8} {:>12.3} {:>9.2}x {:>8} {:>8}",
                mode,
                threads,
                1e3 * s.mean(),
                speedup,
                mem.stash_hits,
                mem.remats
            );
            results.push(obj(vec![
                ("op", "block_bwd_stash_vs_remat_small".into()),
                ("backend", "host".into()),
                ("act_budget", mode.into()),
                ("threads", threads.into()),
                ("ms_per_fwd_bwd_pair", (s.mean() * 1e3).into()),
                ("speedup_vs_remat", speedup.into()),
                ("stash_hits", (mem.stash_hits as usize).into()),
                ("remats", (mem.remats as usize).into()),
            ]));
        }
    }
    println!("(the stashed backward skips the in-call forward recompute entirely)");

    banner("executor call count (instrumentation)");
    println!("exec calls so far: {}", lib.executor().exec_calls());

    let report = obj(vec![
        ("platform", Json::Str(platform)),
        ("elements", n_total.into()),
        ("iters", iters.into()),
        ("results", Json::Arr(results)),
    ]);
    let path = "BENCH_perf.json";
    match std::fs::write(path, report.to_string_pretty()) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }
}
