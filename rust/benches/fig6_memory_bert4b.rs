//! Figure 6 — BERT-4B memory with PyTorch (a) and DeepSpeed ZeRO (b).
//!
//! Paper: (a) AdamA saves 23.2% over gradient accumulation at 4B scale;
//! (b) combined with ZeRO-S1 (`P_os`) it saves 20.1 GB over ZeRO-S1 alone
//! and beats even ZeRO-S2 (`P_os+g`). Analytic model, mb 64, N=8, 8 GPUs.

use adama::config::OptimizerKind;
use adama::memmodel::{peak_memory, Breakdown, DtypePolicy, PaperModel, Scenario, Strategy};

#[path = "support/mod.rs"]
mod support;
use support::{banner, gb, lib_or_exit};

fn row(name: &str, b: &Breakdown) {
    println!(
        "{name:<16} {:>9.2} {:>9.2} {:>9.2} {:>9.2} {:>10.2}",
        gb(b.weights),
        gb(b.gradients),
        gb(b.optimizer_states),
        gb(b.activations),
        gb(b.total())
    );
}

fn main() {
    let _lib = lib_or_exit(); // consistency with other benches
    let model = PaperModel::bert_4b();
    println!("model: {} ({:.2}B params)", model.name, model.params as f64 / 1e9);
    let mk = |strategy| {
        peak_memory(&Scenario {
            model: model.clone(),
            dtype: DtypePolicy::paper_fp32(),
            strategy,
            optimizer: OptimizerKind::AdamGA,
            minibatch_per_gpu: 8, // mb 64 / 8 GPUs
            accum_steps: 8,
            gpus: 8,
        })
    };

    banner("Figure 6a (PyTorch): GA vs AdamA, per-GPU GB");
    println!(
        "{:<16} {:>9} {:>9} {:>9} {:>9} {:>10}",
        "strategy", "weights", "grads", "optstate", "acts", "TOTAL"
    );
    let ga = mk(Strategy::GradAccum);
    let aa = mk(Strategy::AdamA);
    row("grad-accum", &ga);
    row("AdamA", &aa);
    let saving = 1.0 - aa.total() as f64 / ga.total() as f64;
    println!("AdamA saving: {:.1}%  (paper: 23.2%)", 100.0 * saving);

    banner("Figure 6b (DeepSpeed): ZeRO combinations, per-GPU GB");
    println!(
        "{:<16} {:>9} {:>9} {:>9} {:>9} {:>10}",
        "strategy", "weights", "grads", "optstate", "acts", "TOTAL"
    );
    let z1 = mk(Strategy::Zero1GradAccum);
    let z1aa = mk(Strategy::Zero1AdamA);
    let z2 = mk(Strategy::Zero2GradAccum);
    row("ZeRO-S1 (+GA)", &z1);
    row("ZeRO-S1+AdamA", &z1aa);
    row("ZeRO-S2 (+GA)", &z2);
    println!(
        "ZeRO-S1+AdamA saves {:.1} GB vs ZeRO-S1 (paper: 20.1) and {:.1} GB vs ZeRO-S2 (paper: 7.6)",
        gb(z1.total() - z1aa.total()),
        gb(z2.total() - z1aa.total()),
    );
    assert!(z1aa.total() < z2.total() && z2.total() < z1.total());
}
