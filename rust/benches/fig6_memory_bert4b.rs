//! Figure 6 — BERT-4B memory with PyTorch (a) and DeepSpeed ZeRO (b).
//!
//! Paper: (a) AdamA saves 23.2% over gradient accumulation at 4B scale;
//! (b) combined with ZeRO-S1 (`P_os`) it saves 20.1 GB over ZeRO-S1 alone
//! and beats even ZeRO-S2 (`P_os+g`). Analytic model, mb 64, N=8, 8 GPUs.
//!
//! A third section projects the host executor's stash-vs-remat
//! activation coefficients to paper scale: the AdamA gradient saving
//! only survives end-to-end if activations are also managed — this is
//! the number that shows *why* (full stashing multiplies the activation
//! term ~18×; a byte budget buys back recompute where it fits).

use adama::config::OptimizerKind;
use adama::memmodel::{
    peak_memory, Breakdown, DtypePolicy, HostBlockDims, PaperModel, Scenario, Strategy,
};

#[path = "support/mod.rs"]
mod support;
use support::{banner, gb, lib_or_exit};

fn row(name: &str, b: &Breakdown) {
    println!(
        "{name:<16} {:>9.2} {:>9.2} {:>9.2} {:>9.2} {:>10.2}",
        gb(b.weights),
        gb(b.gradients),
        gb(b.optimizer_states),
        gb(b.activations),
        gb(b.total())
    );
}

fn main() {
    let _lib = lib_or_exit(); // consistency with other benches
    let model = PaperModel::bert_4b();
    println!("model: {} ({:.2}B params)", model.name, model.params as f64 / 1e9);
    let mk = |strategy| {
        peak_memory(&Scenario {
            model: model.clone(),
            dtype: DtypePolicy::paper_fp32(),
            strategy,
            optimizer: OptimizerKind::AdamGA,
            minibatch_per_gpu: 8, // mb 64 / 8 GPUs
            accum_steps: 8,
            gpus: 8,
        })
    };

    banner("Figure 6a (PyTorch): GA vs AdamA, per-GPU GB");
    println!(
        "{:<16} {:>9} {:>9} {:>9} {:>9} {:>10}",
        "strategy", "weights", "grads", "optstate", "acts", "TOTAL"
    );
    let ga = mk(Strategy::GradAccum);
    let aa = mk(Strategy::AdamA);
    row("grad-accum", &ga);
    row("AdamA", &aa);
    let saving = 1.0 - aa.total() as f64 / ga.total() as f64;
    println!("AdamA saving: {:.1}%  (paper: 23.2%)", 100.0 * saving);

    banner("Figure 6b (DeepSpeed): ZeRO combinations, per-GPU GB");
    println!(
        "{:<16} {:>9} {:>9} {:>9} {:>9} {:>10}",
        "strategy", "weights", "grads", "optstate", "acts", "TOTAL"
    );
    let z1 = mk(Strategy::Zero1GradAccum);
    let z1aa = mk(Strategy::Zero1AdamA);
    let z2 = mk(Strategy::Zero2GradAccum);
    row("ZeRO-S1 (+GA)", &z1);
    row("ZeRO-S1+AdamA", &z1aa);
    row("ZeRO-S2 (+GA)", &z2);
    println!(
        "ZeRO-S1+AdamA saves {:.1} GB vs ZeRO-S1 (paper: 20.1) and {:.1} GB vs ZeRO-S2 (paper: 7.6)",
        gb(z1.total() - z1aa.total()),
        gb(z2.total() - z1aa.total()),
    );
    assert!(z1aa.total() < z2.total() && z2.total() < z1.total());

    banner("activation policy projection: remat vs full stash at paper scale");
    println!(
        "{:<16} {:>12} {:>16} {:>16}",
        "model", "K (B/tok/l/h)", "acts remat (GB)", "acts stash (GB)"
    );
    for m in [PaperModel::bert_large(), PaperModel::bert_4b()] {
        // per-GPU micro-batch 8, heads sized so head_dim = 64 (BERT-ish)
        let dims = HostBlockDims {
            batch: 8,
            seq: m.seq,
            hidden: m.hidden,
            heads: (m.hidden / 64).max(1),
            ffn: 4 * m.hidden,
        };
        let k_remat = DtypePolicy::runtime_remat().act_coeff as f64;
        let k_stash = dims.stash_act_coeff();
        let tokens = 8 * m.seq;
        let acts = |k: f64| k * (tokens * m.hidden * m.layers) as f64 / 1e9;
        println!(
            "{:<16} {:>5.0} vs {:>4.0} {:>16.2} {:>16.2}",
            m.name,
            k_remat,
            k_stash,
            acts(k_remat),
            acts(k_stash),
        );
        assert!(k_stash > k_remat, "stashing must cost more bytes than remat");
    }
    println!("(a byte budget interpolates: each stashed block saves one forward recompute)");
}
