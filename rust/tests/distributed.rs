//! Distributed data-parallel tests: the paper's Eq. 5–8 equivalence, comm
//! volume shapes, and ZeRO-S1 invariants. All run multi-threaded workers
//! (the concurrent fabric, the default engine) over the shared library.

use std::sync::Arc;

use adama::collective::{run_data_parallel, run_zero1, DpSpec, SyncStrategy, Zero1Spec};
use adama::config::{OptimBackend, OptimizerKind, TrainConfig};
use adama::data::{MarkovCorpus, MicroBatch};
use adama::runtime::ArtifactLibrary;
use adama::{Category, Trainer};

mod common;
use common::library;

const DATA_SEED: u64 = 77;

fn cfg(opt: OptimizerKind, workers: usize, n: usize) -> TrainConfig {
    TrainConfig {
        model: "tiny".into(),
        optimizer: opt,
        backend: OptimBackend::Host,
        accum_steps: n,
        chunk: 16384,
        workers,
        ..TrainConfig::default()
    }
}

/// Reconstruct the union data stream the DP workers consume:
/// per step, worker 0's N micro-batches then worker 1's, etc.
fn union_stream(
    lib: &Arc<ArtifactLibrary>,
    workers: usize,
    n: usize,
    steps: u64,
) -> Vec<Vec<MicroBatch>> {
    let h = lib.manifest().model_config("tiny").unwrap().model.clone();
    let mut corpora: Vec<MarkovCorpus> = (0..workers)
        .map(|r| MarkovCorpus::new(h.vocab, DATA_SEED, 1_000_003 * (r as u64 + 1)))
        .collect();
    (0..steps)
        .map(|_| {
            let mut mbs = Vec::new();
            for c in corpora.iter_mut() {
                mbs.extend(c.minibatch(n, h.microbatch, h.seq));
            }
            mbs
        })
        .collect()
}

fn max_param_diff(a: &[Vec<f32>], b: &[Vec<f32>]) -> f32 {
    a.iter()
        .zip(b)
        .flat_map(|(x, y)| x.iter().zip(y).map(|(p, q)| (p - q).abs()))
        .fold(0.0, f32::max)
}

#[test]
fn dp_state_allreduce_equals_single_device_nm() {
    // THE paper claim (Eq. 5-8): AdamA with M workers × N micro-batches
    // must match single-device AdamA with N·M micro-batches.  After one
    // step the match is float-exact (modulo reduction order); over more
    // steps tiny differences amplify through 1/sqrt(v)≈1/|g| when v is
    // still near zero, so drift is bounded by ~one LR-sized step.
    let lib = library();
    let (m, n) = (2usize, 2usize);
    for (steps, tol) in [(1u64, 2e-5f32), (3u64, 1e-3f32)] {
        let report = run_data_parallel(
            lib.clone(),
            DpSpec::new(
                cfg(OptimizerKind::AdamA, m, n),
                SyncStrategy::OptimizerStates,
                steps,
                DATA_SEED,
            ),
        )
        .unwrap();

        let mut single =
            Trainer::new(lib.clone(), cfg(OptimizerKind::AdamA, 1, n * m)).unwrap();
        for mbs in union_stream(&lib, m, n, steps) {
            single.train_step(&mbs).unwrap();
        }
        let single_params: Vec<Vec<f32>> =
            single.params().iter().map(|p| p.flat.clone()).collect();

        let diff = max_param_diff(&report.final_params, &single_params);
        assert!(diff < tol, "DP(M={m},N={n}) vs single(NM) @ {steps} steps: {diff}");
    }
}

#[test]
fn dp_grad_allreduce_equals_single_device_ga() {
    let lib = library();
    let (m, n) = (2usize, 2usize);
    for (steps, tol) in [(1u64, 2e-5f32), (3u64, 1e-3f32)] {
        let report = run_data_parallel(
            lib.clone(),
            DpSpec::new(
                cfg(OptimizerKind::AdamGA, m, n),
                SyncStrategy::Gradients,
                steps,
                DATA_SEED,
            ),
        )
        .unwrap();

        let mut single =
            Trainer::new(lib.clone(), cfg(OptimizerKind::AdamGA, 1, n * m)).unwrap();
        for mbs in union_stream(&lib, m, n, steps) {
            single.train_step(&mbs).unwrap();
        }
        let single_params: Vec<Vec<f32>> =
            single.params().iter().map(|p| p.flat.clone()).collect();
        let diff = max_param_diff(&report.final_params, &single_params);
        assert!(diff < tol, "DDP-GA vs single GA @ {steps} steps: {diff}");
    }
}

#[test]
fn dp_four_workers_converges_and_ranks_agree() {
    let lib = library();
    let report = run_data_parallel(
        lib,
        DpSpec::new(
            cfg(OptimizerKind::AdamA, 4, 2),
            SyncStrategy::OptimizerStates,
            6,
            DATA_SEED,
        ),
    )
    .unwrap(); // rank-identity asserted inside the runner
    let first = report.losses[0];
    let last = *report.losses.last().unwrap();
    assert!(last < first, "loss {first} -> {last}");
    // per-rank memory surfaces for every rank
    assert_eq!(report.per_rank_memory.len(), 4);
    assert!(report.world_memory().total_peak_bytes() > 0);
}

#[test]
fn comm_volume_state_sync_constant_in_n_grad_sync_linear() {
    // §3.3: state all-reduce is O(1) per mini-batch, naive grad sync O(N).
    let lib = library();
    let vol = |sync, n| {
        let r = run_data_parallel(
            lib.clone(),
            DpSpec::new(cfg(OptimizerKind::AdamA, 2, n), sync, 2, DATA_SEED),
        )
        .unwrap();
        r.comm_bytes as f64
    };
    let s2 = vol(SyncStrategy::OptimizerStates, 2);
    let s8 = vol(SyncStrategy::OptimizerStates, 8);
    // small constant loss-averaging overhead aside, volume is flat in N
    assert!((s8 / s2 - 1.0).abs() < 0.05, "state sync {s2} -> {s8} should be ~constant");

    let g2 = vol(SyncStrategy::GradPerMicrobatch, 2);
    let g8 = vol(SyncStrategy::GradPerMicrobatch, 8);
    assert!(g8 / g2 > 3.0, "naive grad sync must scale with N: {g2} -> {g8}");
}

#[test]
fn comm_volume_state_vs_grad_ratio_is_two() {
    let lib = library();
    let run = |sync, opt| {
        run_data_parallel(lib.clone(), DpSpec::new(cfg(opt, 2, 4), sync, 2, DATA_SEED))
            .unwrap()
            .comm_bytes as f64
    };
    let state = run(SyncStrategy::OptimizerStates, OptimizerKind::AdamA);
    let grad = run(SyncStrategy::Gradients, OptimizerKind::AdamGA);
    let ratio = state / grad;
    assert!(
        (1.8..2.2).contains(&ratio),
        "state sync moves (m,v)=2P vs grads=P: ratio {ratio}"
    );
}

#[test]
fn zero1_ga_matches_ddp_ga() {
    // ZeRO-S1 partitioning must not change the math, only the memory.
    let lib = library();
    let (m, n, steps) = (2usize, 2usize, 3u64);
    let zero = run_zero1(
        lib.clone(),
        Zero1Spec::new(cfg(OptimizerKind::AdamGA, m, n), steps, DATA_SEED),
    )
    .unwrap();
    let ddp = run_data_parallel(
        lib.clone(),
        DpSpec::new(
            cfg(OptimizerKind::AdamGA, m, n),
            SyncStrategy::Gradients,
            steps,
            DATA_SEED,
        ),
    )
    .unwrap();
    let diff = max_param_diff(&zero.final_params, &ddp.final_params);
    assert!(diff < 5e-5, "ZeRO-S1+GA vs DDP+GA: max diff {diff}");
}

#[test]
fn zero1_adama_converges_and_shards_states() {
    let lib = library();
    let (m, n, steps) = (2usize, 2usize, 4u64);
    let report = run_zero1(
        lib.clone(),
        Zero1Spec::new(cfg(OptimizerKind::AdamA, m, n), steps, DATA_SEED),
    )
    .unwrap();
    assert!(*report.losses.last().unwrap() < report.losses[0]);

    // memory shape: optimizer states sharded to ~2P/M; gradients peak at
    // one layer (AdamA release) not the full model.
    let entry = lib.manifest().model_config("tiny").unwrap();
    let spec = adama::model::ModelSpec::from_manifest("tiny", entry).unwrap();
    let p_bytes = spec.total_params() * 4;
    let os = report.memory.peak_optimizer;
    assert!(
        os <= 2 * p_bytes / m + 2 * spec.layers.len() * 4 * m,
        "ZeRO states {os} should be ~2P/M = {}",
        2 * p_bytes / m
    );
    let max_layer = spec.max_layer_params() * 4;
    assert_eq!(report.memory.peak_gradients, max_layer);
    // every rank's snapshot shards states the same way
    assert_eq!(report.per_rank_memory.len(), m);
    for snap in &report.per_rank_memory {
        assert!(snap.tracker.peak_optimizer <= 2 * p_bytes / m + 2 * spec.layers.len() * 4 * m);
    }
}

#[test]
fn zero1_adama_memory_beats_zero1_ga() {
    // Fig 6b shape: ZeRO-S1+AdamA < ZeRO-S1(+GA) on gradients.
    let lib = library();
    let run = |opt| {
        run_zero1(lib.clone(), Zero1Spec::new(cfg(opt, 2, 2), 2, DATA_SEED))
            .unwrap()
            .memory
    };
    let adama_mem = run(OptimizerKind::AdamA);
    let ga_mem = run(OptimizerKind::AdamGA);
    assert!(adama_mem.peak_gradients < ga_mem.peak_gradients);
    // both shard optimizer states equally
    let ratio = adama_mem.peak_optimizer as f64 / ga_mem.peak_optimizer as f64;
    assert!((0.95..1.05).contains(&ratio));
}

#[test]
fn dp_rejects_invalid_combos() {
    let lib = library();
    // state sync without AdamA is an error
    let err = run_data_parallel(
        lib.clone(),
        DpSpec::new(cfg(OptimizerKind::AdamGA, 2, 2), SyncStrategy::OptimizerStates, 1, 1),
    );
    assert!(err.is_err());
    // zero1 with one worker is an error
    let err = run_zero1(lib, Zero1Spec::new(cfg(OptimizerKind::AdamA, 1, 2), 1, 1));
    assert!(err.is_err());
}

#[test]
fn single_worker_dp_matches_plain_trainer() {
    let lib = library();
    let report = run_data_parallel(
        lib.clone(),
        DpSpec::new(cfg(OptimizerKind::AdamA, 1, 2), SyncStrategy::OptimizerStates, 2, DATA_SEED),
    )
    .unwrap();
    let h = lib.manifest().model_config("tiny").unwrap().model.clone();
    let mut t = Trainer::new(lib, cfg(OptimizerKind::AdamA, 1, 2)).unwrap();
    let mut c = MarkovCorpus::new(h.vocab, DATA_SEED, 1_000_003);
    for _ in 0..2 {
        let mbs = c.minibatch(2, h.microbatch, h.seq);
        t.train_step(&mbs).unwrap();
    }
    let single: Vec<Vec<f32>> = t.params().iter().map(|p| p.flat.clone()).collect();
    let diff = max_param_diff(&report.final_params, &single);
    assert!(diff < 1e-6, "M=1 DP must be bit-ish identical: {diff}");
}

#[test]
fn tracker_gradient_category_zero_when_idle() {
    // after a run, transient gradient allocations must balance out
    let lib = library();
    let mut t = Trainer::new(lib, cfg(OptimizerKind::AdamA, 1, 2)).unwrap();
    let h = t.spec().hyper.clone();
    let mut c = MarkovCorpus::new(h.vocab, 1, 2);
    t.train_step(&c.minibatch(2, h.microbatch, h.seq)).unwrap();
    assert_eq!(t.tracker().live(Category::Gradients), 0);
    assert_eq!(t.tracker().live(Category::Activations), 0);
}
