//! Property-based tests (hand-rolled sweeps; proptest is unavailable in
//! the offline dep set — random cases are generated from the in-tree
//! deterministic RNG, with the failing seed printed on assert).
//!
//! Invariants covered (DESIGN.md §5):
//!   * coordinator math: AdamA(N=1) ≡ fused Adam, for random states;
//!   * m_t identical Adam vs AdamA for any N; v_t = Σg² exactly;
//!   * routing/chunking: chunk_ranges covers exactly, for random sizes;
//!   * pool chunking: partition(n, threads) covers 0..n exactly for
//!     arbitrary n/threads (incl. n < threads and n = 0), balanced ±1;
//!   * pool numerics: parallel matmul ≡ serial reference within 0 ULP
//!     (the per-cell dot-product order is unchanged by the row split);
//!   * GEMM engines: the packed, cache-blocked engine ≡ the naive loops
//!     bit-for-bit at random shapes, incl. sub-tile and block-crossing;
//!   * ring collectives: all-reduce ≡ sequential sum for random worlds;
//!   * shard layout: reduce-scatter ownership partitions the buffer;
//!   * batching/state: optimizer state bytes are conserved across steps;
//!   * memmodel monotonicity: more GPUs/N never increases per-GPU peak.

use adama::collective::{CommGroup, CommHandle};
use adama::memmodel::{peak_memory, DtypePolicy, PaperModel, Scenario, Strategy};
use adama::optim::host_math;
use adama::runtime::hostexec::math;
use adama::runtime::pool::{partition, ThreadPool};
use adama::runtime::simd;
use adama::runtime::GemmMode;
use adama::tensor::{chunk_ranges, Rng};

const B1: f32 = 0.9;
const B2: f32 = 0.999;
const EPS: f32 = 1e-8;

fn randvec(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| scale * rng.normal()).collect()
}

#[test]
fn prop_adama_n1_equals_fused_adam() {
    for seed in 0..50u64 {
        let mut rng = Rng::new(seed);
        let n = 1 + rng.below(300);
        let g = randvec(&mut rng, n, 2.0);
        let m0 = randvec(&mut rng, n, 0.5);
        let v0: Vec<f32> = randvec(&mut rng, n, 0.5).iter().map(|x| x.abs()).collect();
        let p0 = randvec(&mut rng, n, 1.0);
        let (lr, bc1, bc2) = (1e-3, 0.1, 0.001);

        let (mut p1, mut m1, mut v1) = (p0.clone(), m0.clone(), v0.clone());
        host_math::adam_full(&mut p1, &mut m1, &mut v1, &g, lr, bc1, bc2, B1, B2, EPS);

        let (mut p2, mut m2, mut v2) = (p0, m0, v0);
        host_math::scale(&mut m2, B1);
        host_math::scale(&mut v2, B2);
        host_math::adama_acc(&mut m2, &mut v2, &g, 1.0, B1, B2);
        host_math::adam_update(&mut p2, &m2, &v2, lr, bc1, bc2, EPS);

        for i in 0..n {
            assert!((p1[i] - p2[i]).abs() < 1e-6, "seed {seed} idx {i}");
            assert!((m1[i] - m2[i]).abs() < 1e-6, "seed {seed} idx {i}");
            assert!((v1[i] - v2[i]).abs() < 1e-7, "seed {seed} idx {i}");
        }
    }
}

#[test]
fn prop_m_identical_v_sum_of_squares_any_n() {
    for seed in 0..30u64 {
        let mut rng = Rng::new(1000 + seed);
        let d = 1 + rng.below(200);
        let n_micro = 2 + rng.below(7);
        let grads: Vec<Vec<f32>> =
            (0..n_micro).map(|_| randvec(&mut rng, d, 1.5)).collect();
        let m0 = randvec(&mut rng, d, 0.3);
        let v0: Vec<f32> = randvec(&mut rng, d, 0.3).iter().map(|x| x.abs()).collect();
        let s = 1.0 / n_micro as f32;

        // Adam: accumulate then fold
        let mut gsum = vec![0.0f32; d];
        for g in &grads {
            host_math::grad_acc(&mut gsum, g, s);
        }
        let m_adam: Vec<f32> =
            m0.iter().zip(&gsum).map(|(m, g)| B1 * m + (1.0 - B1) * g).collect();

        // AdamA: decay + integrate each
        let mut m_a = m0.clone();
        let mut v_a = v0.clone();
        host_math::scale(&mut m_a, B1);
        host_math::scale(&mut v_a, B2);
        for g in &grads {
            host_math::adama_acc(&mut m_a, &mut v_a, g, s, B1, B2);
        }

        for i in 0..d {
            assert!((m_adam[i] - m_a[i]).abs() < 1e-5, "m differs: seed {seed}");
            let want_v: f32 = B2 * v0[i]
                + (1.0 - B2) * grads.iter().map(|g| (g[i] * s) * (g[i] * s)).sum::<f32>();
            assert!((v_a[i] - want_v).abs() < 1e-6, "v differs: seed {seed}");
        }
    }
}

#[test]
fn prop_chunk_ranges_partition_exactly() {
    for seed in 0..200u64 {
        let mut rng = Rng::new(2000 + seed);
        let total = rng.below(100_000);
        let chunk = 1 + rng.below(5000);
        let ranges = chunk_ranges(total, chunk);
        let mut expect_off = 0usize;
        for (i, (off, len)) in ranges.iter().enumerate() {
            assert_eq!(*off, expect_off, "seed {seed}");
            assert!(*len > 0 && *len <= chunk);
            if i + 1 < ranges.len() {
                assert_eq!(*len, chunk, "only the tail may be partial: seed {seed}");
            }
            expect_off += len;
        }
        assert_eq!(expect_off, total, "seed {seed}");
    }
}

#[test]
fn prop_pool_partition_covers_exactly() {
    for seed in 0..300u64 {
        let mut rng = Rng::new(7000 + seed);
        let n = rng.below(10_000); // includes 0 and n < parts cases
        let parts = 1 + rng.below(12);
        let ranges = partition(n, parts);
        assert!(ranges.len() <= parts, "seed {seed}");
        assert_eq!(ranges.len(), parts.min(n), "seed {seed}: range count");
        let mut off = 0usize;
        let mut sizes = Vec::new();
        for &(o, l) in &ranges {
            assert_eq!(o, off, "seed {seed}: non-contiguous");
            assert!(l > 0, "seed {seed}: empty range");
            sizes.push(l);
            off += l;
        }
        assert_eq!(off, n, "seed {seed}: does not cover 0..{n}");
        if let (Some(mn), Some(mx)) = (sizes.iter().min(), sizes.iter().max()) {
            assert!(mx - mn <= 1, "seed {seed}: unbalanced {sizes:?}");
        }
    }
    // pinned edges: n = 0, n < threads, exact division
    assert!(partition(0, 4).is_empty());
    assert_eq!(partition(3, 8).len(), 3);
    assert_eq!(partition(8, 4), vec![(0, 2), (2, 2), (4, 2), (6, 2)]);
}

#[test]
fn prop_parallel_matmul_equals_serial_within_0_ulp() {
    // The row split must leave every per-cell accumulation order intact,
    // so parallel == serial == hand-rolled reference *bitwise* (0 ULP) —
    // and the SIMD axpy rows (level from ADAMA_SIMD, so the CI matrix
    // sweeps scalar and vector) must not change that.
    let lvl = simd::Level::from_env().expect("valid ADAMA_SIMD");
    let gm = GemmMode::from_env().expect("valid ADAMA_GEMM");
    let mut panel = Vec::new();
    let serial = ThreadPool::new(1);
    for seed in 0..25u64 {
        let mut rng = Rng::new(8000 + seed);
        let threads = 2 + rng.below(7);
        let par = ThreadPool::new(threads);
        // m·n above the pool's inline cutoff so the split is actually live
        let m = 33 + rng.below(31);
        let n = 33 + rng.below(31);
        let k = 1 + rng.below(48);
        let a = randvec(&mut rng, m * k, 1.5);
        let b = randvec(&mut rng, k * n, 1.5);

        // matmul: reference with the serial ikj loop order
        let mut reference = vec![0.0f32; m * n];
        for i in 0..m {
            let row = &mut reference[i * n..(i + 1) * n];
            for p in 0..k {
                let aip = a[i * k + p];
                for (o, &bv) in row.iter_mut().zip(&b[p * n..(p + 1) * n]) {
                    *o += aip * bv;
                }
            }
        }
        let mut got_s = vec![0.0f32; m * n];
        let mut got_p = vec![0.0f32; m * n];
        math::matmul(&serial, lvl, gm, &mut panel, &a, &b, m, k, n, &mut got_s);
        math::matmul(&par, lvl, gm, &mut panel, &a, &b, m, k, n, &mut got_p);
        for i in 0..m * n {
            assert_eq!(reference[i].to_bits(), got_s[i].to_bits(), "seed {seed}: serial matmul");
            assert_eq!(
                reference[i].to_bits(),
                got_p[i].to_bits(),
                "seed {seed}: parallel matmul ({threads} threads)"
            );
        }

        // matmul_tn: a:[p,m], b:[p,n], reference accumulates r ascending
        let p_rows = 1 + rng.below(48);
        let at = randvec(&mut rng, p_rows * m, 1.0);
        let bt = randvec(&mut rng, p_rows * n, 1.0);
        let mut ref_tn = vec![0.0f32; m * n];
        for r in 0..p_rows {
            for i in 0..m {
                let ari = at[r * m + i];
                for (o, &bv) in
                    ref_tn[i * n..(i + 1) * n].iter_mut().zip(&bt[r * n..(r + 1) * n])
                {
                    *o += ari * bv;
                }
            }
        }
        let mut got_tn = vec![0.0f32; m * n];
        math::matmul_tn(&par, lvl, gm, &mut panel, &at, &bt, p_rows, m, n, &mut got_tn);
        for i in 0..m * n {
            assert_eq!(ref_tn[i].to_bits(), got_tn[i].to_bits(), "seed {seed}: matmul_tn");
        }

        // matmul_nt: a:[m,k], b:[n,k], plain dot products
        let bn = randvec(&mut rng, n * k, 1.0);
        let mut ref_nt = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f32;
                for (&av, &bv) in a[i * k..(i + 1) * k].iter().zip(&bn[j * k..(j + 1) * k]) {
                    acc += av * bv;
                }
                ref_nt[i * n + j] = acc;
            }
        }
        let mut got_nt = vec![0.0f32; m * n];
        math::matmul_nt(&par, lvl, gm, &mut panel, &a, &bn, m, k, n, &mut got_nt);
        for i in 0..m * n {
            assert_eq!(ref_nt[i].to_bits(), got_nt[i].to_bits(), "seed {seed}: matmul_nt");
        }
    }
}

#[test]
fn prop_packed_gemm_bitwise_equals_naive() {
    // Cache blocking must not move a single fold: the packed engine and
    // the naive loops are bit-identical for every variant at shapes
    // spanning sub-tile (below one lane/row tile in every dimension),
    // sub-block, and block-crossing (k > KC, n > NC) sizes, at 1 and
    // several threads. The SIMD level comes from ADAMA_SIMD so the CI
    // matrix sweeps scalar and vector lanes through the same shapes.
    let lvl = simd::Level::from_env().expect("valid ADAMA_SIMD");
    // pinned edges: every dimension degenerate or crossing a block edge
    let mut shapes = vec![
        (1usize, 1usize, 1usize),
        (1, 300, 1),
        (3, 1, 5),
        (2, 257, 259),
        (3, 270, 261),
        (5, 513, 7),
        (7, 9, 300),
    ];
    let mut shape_rng = Rng::new(9100);
    for _ in 0..12 {
        shapes.push((
            1 + shape_rng.below(40),
            1 + shape_rng.below(70),
            1 + shape_rng.below(40),
        ));
    }
    for (si, &(m, k, n)) in shapes.iter().enumerate() {
        let mut rng = Rng::new(9200 + si as u64);
        let threads = 1 + rng.below(4);
        let pool = ThreadPool::new(threads);
        let a = randvec(&mut rng, m * k, 1.2);
        let b = randvec(&mut rng, k * n, 1.2);
        let at = randvec(&mut rng, k * m, 1.2); // [p=k, m] for the TN form
        let bn = randvec(&mut rng, n * k, 1.2); // [n, k] for the NT form
        let mut panel = Vec::new();
        let run = |gm: GemmMode, panel: &mut Vec<f32>| {
            let mut nn = vec![0.0f32; m * n];
            math::matmul(&pool, lvl, gm, panel, &a, &b, m, k, n, &mut nn);
            let mut tn = vec![0.0f32; m * n];
            math::matmul_tn(&pool, lvl, gm, panel, &at, &b, k, m, n, &mut tn);
            let mut nt = vec![0.0f32; m * n];
            math::matmul_nt(&pool, lvl, gm, panel, &a, &bn, m, k, n, &mut nt);
            (nn, tn, nt)
        };
        let naive = run(GemmMode::Naive, &mut panel);
        let packed = run(GemmMode::Packed, &mut panel);
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
        assert_eq!(bits(&naive.0), bits(&packed.0), "NN m={m} k={k} n={n} t={threads}");
        assert_eq!(bits(&naive.1), bits(&packed.1), "TN m={m} k={k} n={n} t={threads}");
        assert_eq!(bits(&naive.2), bits(&packed.2), "NT m={m} k={k} n={n} t={threads}");
    }
}

#[test]
fn prop_ring_allreduce_equals_sum() {
    for seed in 0..10u64 {
        let mut rng = Rng::new(3000 + seed);
        let world = 2 + rng.below(5);
        let n = 1 + rng.below(300);
        let inputs: Vec<Vec<f32>> = (0..world)
            .map(|w| {
                let mut r = Rng::new(seed * 100 + w as u64);
                randvec(&mut r, n, 1.0)
            })
            .collect();
        let want: Vec<f32> =
            (0..n).map(|i| inputs.iter().map(|v| v[i]).sum()).collect();

        let handles = CommGroup::new(world);
        let mut joins = Vec::new();
        for h in handles {
            let mine = inputs[h.rank()].clone();
            joins.push(std::thread::spawn(move || {
                let mut data = mine;
                h.all_reduce_sum(&mut data).unwrap();
                data
            }));
        }
        for j in joins {
            let got = j.join().unwrap();
            for i in 0..n {
                assert!((got[i] - want[i]).abs() < 1e-4 * want[i].abs().max(1.0),
                    "seed {seed} idx {i}: {} vs {}", got[i], want[i]);
            }
        }
    }
}

#[test]
fn prop_fabric_reduce_order_invariant_under_injected_delays() {
    // The fabric's reduction order is a pure function of rank indices:
    // random per-rank sleeps (arrival-order scrambling) must never change
    // a single bit relative to the single-threaded serial oracle, for
    // random worlds/lengths (incl. zero-length shards when len < world)
    // and both topologies.
    use adama::collective::fabric::{serial, Fabric, Topology};
    use adama::collective::CommStats;
    use std::sync::Arc;

    for seed in 0..12u64 {
        let mut rng = Rng::new(6000 + seed);
        let world = 1 + rng.below(6);
        let n = rng.below(40); // may be < world: some shards empty
        let topo = if rng.below(2) == 0 { Topology::Ring } else { Topology::Tree };
        let inputs: Vec<Vec<f32>> = (0..world)
            .map(|w| {
                let mut r = Rng::new(seed * 131 + w as u64);
                randvec(&mut r, n, 1.0)
            })
            .collect();
        let mut oracle = inputs.clone();
        serial::all_reduce_sum(topo, &mut oracle, &CommStats::default()).unwrap();

        let delays: Vec<u64> = (0..world).map(|_| rng.below(6) as u64).collect();
        let inputs = Arc::new(inputs);
        let handles = Fabric::with_topology(world, topo);
        let mut joins = Vec::new();
        for h in handles {
            let inputs = inputs.clone();
            let delay = delays[h.rank()];
            joins.push(std::thread::spawn(move || {
                std::thread::sleep(std::time::Duration::from_millis(delay));
                let mut data = inputs[h.rank()].clone();
                h.all_reduce_sum(&mut data).unwrap();
                data
            }));
        }
        for (r, j) in joins.into_iter().enumerate() {
            let got = j.join().unwrap();
            let got: Vec<u32> = got.iter().map(|x| x.to_bits()).collect();
            let want: Vec<u32> = oracle[r].iter().map(|x| x.to_bits()).collect();
            assert_eq!(got, want, "seed {seed} world {world} n {n} {topo:?} rank {r}");
        }
    }
}

#[test]
fn prop_fabric_async_issue_invariant_under_delays_and_buckets() {
    // Async issue + random bucket groupings + random per-rank delays
    // (completion-order scrambling) must be bit-identical — results AND
    // ledger — to the serial oracle reducing each buffer individually:
    // overlap changes *when* work happens, never *what* is folded.
    use adama::collective::fabric::{serial, Fabric, Topology};
    use adama::collective::{CommStats, Ticket};
    use std::sync::Arc;

    for seed in 0..10u64 {
        let mut rng = Rng::new(8000 + seed);
        let world = 2 + rng.below(4);
        let k = 1 + rng.below(5); // buffers (layer gradients) per rank
        let lens: Vec<usize> = (0..k).map(|_| rng.below(40)).collect();
        let topo = if rng.below(2) == 0 { Topology::Ring } else { Topology::Tree };
        // random bucket cuts — identical on every rank (the contract:
        // boundaries are a pure function of the shared layer sizes)
        let mut groups: Vec<Vec<usize>> = Vec::new();
        for idx in 0..k {
            if groups.is_empty() || rng.below(2) == 0 {
                groups.push(vec![idx]);
            } else {
                groups.last_mut().unwrap().push(idx);
            }
        }
        let inputs: Vec<Vec<Vec<f32>>> = (0..world)
            .map(|w| {
                let mut r = Rng::new(seed * 733 + w as u64);
                lens.iter().map(|&n| randvec(&mut r, n, 1.0)).collect()
            })
            .collect();

        // serial oracle: reduce-scatter each buffer on its own
        let oracle_stats = CommStats::default();
        let mut oracle: Vec<Vec<Vec<f32>>> = vec![Vec::new(); world];
        for bi in 0..k {
            let mut bufs: Vec<Vec<f32>> =
                (0..world).map(|w| inputs[w][bi].clone()).collect();
            let owned = serial::reduce_scatter_sum(topo, &mut bufs, &oracle_stats).unwrap();
            for w in 0..world {
                oracle[w].push(bufs[w][owned[w].clone()].to_vec());
            }
        }

        let delays: Vec<u64> = (0..world).map(|_| rng.below(6) as u64).collect();
        let handles = Fabric::with_topology(world, topo);
        let async_stats = handles[0].stats().clone();
        let inputs = Arc::new(inputs);
        let groups = Arc::new(groups);
        let mut joins = Vec::new();
        for h in handles {
            let inputs = inputs.clone();
            let groups = groups.clone();
            let delay = delays[h.rank()];
            joins.push(std::thread::spawn(move || {
                let mine = &inputs[h.rank()];
                // issue every bucket before waiting any, jittering the
                // issue points so ranks are mid-compute at different times
                let tickets: Vec<Ticket> = groups
                    .iter()
                    .map(|g| {
                        std::thread::sleep(std::time::Duration::from_millis(delay));
                        h.reduce_scatter_many_async(
                            g.iter().map(|&bi| mine[bi].clone()).collect(),
                        )
                    })
                    .collect();
                let mut out: Vec<Vec<f32>> = Vec::new();
                for t in tickets {
                    for rb in t.wait().unwrap() {
                        out.push(rb.data[rb.owned].to_vec());
                    }
                }
                out
            }));
        }
        for (w, j) in joins.into_iter().enumerate() {
            let got = j.join().unwrap();
            assert_eq!(got.len(), k, "seed {seed} rank {w}");
            for bi in 0..k {
                let g: Vec<u32> = got[bi].iter().map(|x| x.to_bits()).collect();
                let o: Vec<u32> = oracle[w][bi].iter().map(|x| x.to_bits()).collect();
                assert_eq!(g, o, "seed {seed} {topo:?} world {world} rank {w} buf {bi}");
            }
        }
        assert_eq!(async_stats.op_count(), oracle_stats.op_count(), "seed {seed} ops");
        assert_eq!(async_stats.bytes(), oracle_stats.bytes(), "seed {seed} bytes");
    }
}

#[test]
fn prop_zero1_async_random_buckets_match_sync_run() {
    // Run-level form of the invariant: ZeRO-S1+AdamA with async issue and
    // a random bucket threshold — multithreaded ranks, both topologies —
    // produces bit-identical losses, params and ledgers to the
    // synchronous flow.
    use adama::collective::{run_zero1, CollectiveEngine, Topology, Zero1Spec};
    use adama::config::{OptimBackend, OptimizerKind, TrainConfig};
    use adama::runtime::Library;

    let lib = Library::open_default().expect("opening execution library");
    for seed in 0..3u64 {
        let mut rng = Rng::new(9000 + seed);
        let topo = if rng.below(2) == 0 { Topology::Ring } else { Topology::Tree };
        let bucket = [0usize, 1 << 10, 16 << 10, 1 << 30][rng.below(4)];
        let cfg = TrainConfig {
            model: "tiny".into(),
            optimizer: OptimizerKind::AdamA,
            backend: OptimBackend::Host,
            accum_steps: 2,
            chunk: 16384,
            workers: 2,
            ..TrainConfig::default()
        };
        let run = |async_issue: bool| {
            run_zero1(
                lib.clone(),
                Zero1Spec::new(cfg.clone(), 1, 41)
                    .with_engine(CollectiveEngine::Fabric)
                    .with_topology(topo)
                    .with_rank_threads(2)
                    .with_async(async_issue)
                    .with_bucket_bytes(bucket),
            )
            .unwrap()
        };
        let sync = run(false);
        let asyn = run(true);
        let tag = format!("seed {seed} {topo:?} bucket {bucket}");
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
        assert_eq!(bits(&asyn.losses), bits(&sync.losses), "{tag}: losses");
        for (l, (a, s)) in asyn.final_params.iter().zip(&sync.final_params).enumerate() {
            assert_eq!(bits(a), bits(s), "{tag}: layer {l} params");
        }
        assert_eq!(asyn.comm_bytes, sync.comm_bytes, "{tag}: wire ledger");
        assert_eq!(asyn.comm_ops, sync.comm_ops, "{tag}: op ledger");
    }
}

#[test]
fn prop_shard_ranges_partition() {
    for seed in 0..100u64 {
        let mut rng = Rng::new(4000 + seed);
        let len = rng.below(10_000);
        let world = 1 + rng.below(16);
        let shards = CommHandle::shard_ranges(len, world);
        assert_eq!(shards.len(), world);
        let mut off = 0;
        for s in &shards {
            assert_eq!(s.start, off, "seed {seed}");
            off = s.end;
        }
        assert_eq!(off, len, "seed {seed}");
        // balanced within 1
        let sizes: Vec<usize> = shards.iter().map(|s| s.len()).collect();
        let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
        assert!(mx - mn <= 1, "seed {seed}: unbalanced {sizes:?}");
    }
}

#[test]
fn prop_memmodel_monotone() {
    // per-GPU peak never increases with more accumulation steps or more
    // GPUs (for partitioned strategies).
    for seed in 0..40u64 {
        let mut rng = Rng::new(5000 + seed);
        let params = 100_000_000 + rng.below(10_000_000_000) as u64;
        let model = PaperModel::gpt3_scaled("p", params);
        let mk = |strategy, n: u64, gpus: u64| {
            peak_memory(&Scenario {
                model: model.clone(),
                dtype: DtypePolicy::paper_fp32(),
                strategy,
                optimizer: adama::config::OptimizerKind::AdamGA,
                minibatch_per_gpu: 64,
                accum_steps: n,
                gpus,
            })
            .total()
        };
        for strat in [Strategy::GradAccum, Strategy::AdamA] {
            assert!(mk(strat, 8, 8) <= mk(strat, 2, 8), "seed {seed} {strat:?}");
        }
        assert!(
            mk(Strategy::Zero1AdamA, 8, 16) <= mk(Strategy::Zero1AdamA, 8, 8),
            "seed {seed}"
        );
        // AdamA never worse than GA
        assert!(mk(Strategy::AdamA, 4, 8) <= mk(Strategy::GradAccum, 4, 8));
    }
}

#[test]
fn prop_update_magnitude_bounded_by_lr_over_bc1() {
    // |Δp| per Adam step is bounded by lr·(sqrt(bc2)/bc1)·(|m̂|/(√v̂))…
    // with v from the same g, the classic bound |Δp| ≤ lr·bc-factor holds
    // when m and v come from the same gradient history. Check the fused
    // step on fresh state: |Δp| ≤ lr / (sqrt(1-β2)) approx bound.
    for seed in 0..30u64 {
        let mut rng = Rng::new(6000 + seed);
        let n = 1 + rng.below(100);
        let g = randvec(&mut rng, n, 10.0);
        let mut p = randvec(&mut rng, n, 1.0);
        let p0 = p.clone();
        let mut m = vec![0.0f32; n];
        let mut v = vec![0.0f32; n];
        let lr = 1e-3f32;
        let (bc1, bc2) = (1.0 - B1, 1.0 - B2);
        host_math::adam_full(&mut p, &mut m, &mut v, &g, lr, bc1, bc2, B1, B2, EPS);
        let bound = lr / (1.0 - B2).sqrt() * 1.001;
        for i in 0..n {
            assert!(
                (p[i] - p0[i]).abs() <= bound,
                "seed {seed}: step {} exceeds bound {bound}",
                (p[i] - p0[i]).abs()
            );
        }
    }
}
