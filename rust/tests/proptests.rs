//! Property-based tests (hand-rolled sweeps; proptest is unavailable in
//! the offline dep set — random cases are generated from the in-tree
//! deterministic RNG, with the failing seed printed on assert).
//!
//! Invariants covered (DESIGN.md §5):
//!   * coordinator math: AdamA(N=1) ≡ fused Adam, for random states;
//!   * m_t identical Adam vs AdamA for any N; v_t = Σg² exactly;
//!   * routing/chunking: chunk_ranges covers exactly, for random sizes;
//!   * ring collectives: all-reduce ≡ sequential sum for random worlds;
//!   * shard layout: reduce-scatter ownership partitions the buffer;
//!   * batching/state: optimizer state bytes are conserved across steps;
//!   * memmodel monotonicity: more GPUs/N never increases per-GPU peak.

use adama::collective::{CommGroup, CommHandle};
use adama::memmodel::{peak_memory, DtypePolicy, PaperModel, Scenario, Strategy};
use adama::optim::host_math;
use adama::tensor::{chunk_ranges, Rng};

const B1: f32 = 0.9;
const B2: f32 = 0.999;
const EPS: f32 = 1e-8;

fn randvec(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| scale * rng.normal()).collect()
}

#[test]
fn prop_adama_n1_equals_fused_adam() {
    for seed in 0..50u64 {
        let mut rng = Rng::new(seed);
        let n = 1 + rng.below(300);
        let g = randvec(&mut rng, n, 2.0);
        let m0 = randvec(&mut rng, n, 0.5);
        let v0: Vec<f32> = randvec(&mut rng, n, 0.5).iter().map(|x| x.abs()).collect();
        let p0 = randvec(&mut rng, n, 1.0);
        let (lr, bc1, bc2) = (1e-3, 0.1, 0.001);

        let (mut p1, mut m1, mut v1) = (p0.clone(), m0.clone(), v0.clone());
        host_math::adam_full(&mut p1, &mut m1, &mut v1, &g, lr, bc1, bc2, B1, B2, EPS);

        let (mut p2, mut m2, mut v2) = (p0, m0, v0);
        host_math::scale(&mut m2, B1);
        host_math::scale(&mut v2, B2);
        host_math::adama_acc(&mut m2, &mut v2, &g, 1.0, B1, B2);
        host_math::adam_update(&mut p2, &m2, &v2, lr, bc1, bc2, EPS);

        for i in 0..n {
            assert!((p1[i] - p2[i]).abs() < 1e-6, "seed {seed} idx {i}");
            assert!((m1[i] - m2[i]).abs() < 1e-6, "seed {seed} idx {i}");
            assert!((v1[i] - v2[i]).abs() < 1e-7, "seed {seed} idx {i}");
        }
    }
}

#[test]
fn prop_m_identical_v_sum_of_squares_any_n() {
    for seed in 0..30u64 {
        let mut rng = Rng::new(1000 + seed);
        let d = 1 + rng.below(200);
        let n_micro = 2 + rng.below(7);
        let grads: Vec<Vec<f32>> =
            (0..n_micro).map(|_| randvec(&mut rng, d, 1.5)).collect();
        let m0 = randvec(&mut rng, d, 0.3);
        let v0: Vec<f32> = randvec(&mut rng, d, 0.3).iter().map(|x| x.abs()).collect();
        let s = 1.0 / n_micro as f32;

        // Adam: accumulate then fold
        let mut gsum = vec![0.0f32; d];
        for g in &grads {
            host_math::grad_acc(&mut gsum, g, s);
        }
        let m_adam: Vec<f32> =
            m0.iter().zip(&gsum).map(|(m, g)| B1 * m + (1.0 - B1) * g).collect();

        // AdamA: decay + integrate each
        let mut m_a = m0.clone();
        let mut v_a = v0.clone();
        host_math::scale(&mut m_a, B1);
        host_math::scale(&mut v_a, B2);
        for g in &grads {
            host_math::adama_acc(&mut m_a, &mut v_a, g, s, B1, B2);
        }

        for i in 0..d {
            assert!((m_adam[i] - m_a[i]).abs() < 1e-5, "m differs: seed {seed}");
            let want_v: f32 = B2 * v0[i]
                + (1.0 - B2) * grads.iter().map(|g| (g[i] * s) * (g[i] * s)).sum::<f32>();
            assert!((v_a[i] - want_v).abs() < 1e-6, "v differs: seed {seed}");
        }
    }
}

#[test]
fn prop_chunk_ranges_partition_exactly() {
    for seed in 0..200u64 {
        let mut rng = Rng::new(2000 + seed);
        let total = rng.below(100_000);
        let chunk = 1 + rng.below(5000);
        let ranges = chunk_ranges(total, chunk);
        let mut expect_off = 0usize;
        for (i, (off, len)) in ranges.iter().enumerate() {
            assert_eq!(*off, expect_off, "seed {seed}");
            assert!(*len > 0 && *len <= chunk);
            if i + 1 < ranges.len() {
                assert_eq!(*len, chunk, "only the tail may be partial: seed {seed}");
            }
            expect_off += len;
        }
        assert_eq!(expect_off, total, "seed {seed}");
    }
}

#[test]
fn prop_ring_allreduce_equals_sum() {
    for seed in 0..10u64 {
        let mut rng = Rng::new(3000 + seed);
        let world = 2 + rng.below(5);
        let n = 1 + rng.below(300);
        let inputs: Vec<Vec<f32>> = (0..world)
            .map(|w| {
                let mut r = Rng::new(seed * 100 + w as u64);
                randvec(&mut r, n, 1.0)
            })
            .collect();
        let want: Vec<f32> =
            (0..n).map(|i| inputs.iter().map(|v| v[i]).sum()).collect();

        let handles = CommGroup::new(world);
        let mut joins = Vec::new();
        for h in handles {
            let mine = inputs[h.rank()].clone();
            joins.push(std::thread::spawn(move || {
                let mut data = mine;
                h.all_reduce_sum(&mut data).unwrap();
                data
            }));
        }
        for j in joins {
            let got = j.join().unwrap();
            for i in 0..n {
                assert!((got[i] - want[i]).abs() < 1e-4 * want[i].abs().max(1.0),
                    "seed {seed} idx {i}: {} vs {}", got[i], want[i]);
            }
        }
    }
}

#[test]
fn prop_shard_ranges_partition() {
    for seed in 0..100u64 {
        let mut rng = Rng::new(4000 + seed);
        let len = rng.below(10_000);
        let world = 1 + rng.below(16);
        let shards = CommHandle::shard_ranges(len, world);
        assert_eq!(shards.len(), world);
        let mut off = 0;
        for s in &shards {
            assert_eq!(s.start, off, "seed {seed}");
            off = s.end;
        }
        assert_eq!(off, len, "seed {seed}");
        // balanced within 1
        let sizes: Vec<usize> = shards.iter().map(|s| s.len()).collect();
        let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
        assert!(mx - mn <= 1, "seed {seed}: unbalanced {sizes:?}");
    }
}

#[test]
fn prop_memmodel_monotone() {
    // per-GPU peak never increases with more accumulation steps or more
    // GPUs (for partitioned strategies).
    for seed in 0..40u64 {
        let mut rng = Rng::new(5000 + seed);
        let params = 100_000_000 + rng.below(10_000_000_000) as u64;
        let model = PaperModel::gpt3_scaled("p", params);
        let mk = |strategy, n: u64, gpus: u64| {
            peak_memory(&Scenario {
                model: model.clone(),
                dtype: DtypePolicy::paper_fp32(),
                strategy,
                optimizer: adama::config::OptimizerKind::AdamGA,
                minibatch_per_gpu: 64,
                accum_steps: n,
                gpus,
            })
            .total()
        };
        for strat in [Strategy::GradAccum, Strategy::AdamA] {
            assert!(mk(strat, 8, 8) <= mk(strat, 2, 8), "seed {seed} {strat:?}");
        }
        assert!(
            mk(Strategy::Zero1AdamA, 8, 16) <= mk(Strategy::Zero1AdamA, 8, 8),
            "seed {seed}"
        );
        // AdamA never worse than GA
        assert!(mk(Strategy::AdamA, 4, 8) <= mk(Strategy::GradAccum, 4, 8));
    }
}

#[test]
fn prop_update_magnitude_bounded_by_lr_over_bc1() {
    // |Δp| per Adam step is bounded by lr·(sqrt(bc2)/bc1)·(|m̂|/(√v̂))…
    // with v from the same g, the classic bound |Δp| ≤ lr·bc-factor holds
    // when m and v come from the same gradient history. Check the fused
    // step on fresh state: |Δp| ≤ lr / (sqrt(1-β2)) approx bound.
    for seed in 0..30u64 {
        let mut rng = Rng::new(6000 + seed);
        let n = 1 + rng.below(100);
        let g = randvec(&mut rng, n, 10.0);
        let mut p = randvec(&mut rng, n, 1.0);
        let p0 = p.clone();
        let mut m = vec![0.0f32; n];
        let mut v = vec![0.0f32; n];
        let lr = 1e-3f32;
        let (bc1, bc2) = (1.0 - B1, 1.0 - B2);
        host_math::adam_full(&mut p, &mut m, &mut v, &g, lr, bc1, bc2, B1, B2, EPS);
        let bound = lr / (1.0 - B2).sqrt() * 1.001;
        for i in 0..n {
            assert!(
                (p[i] - p0[i]).abs() <= bound,
                "seed {seed}: step {} exceeds bound {bound}",
                (p[i] - p0[i]).abs()
            );
        }
    }
}
