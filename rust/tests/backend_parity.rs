//! Backend-parity tests (no artifacts required — run on the host
//! executor): AdamA through the chunked kernel-program path must match
//! plain host-math Adam-then-accumulate semantics **bit for bit**, across
//! micro-batch counts, plus end-to-end host-executor smoke tests.
//!
//! The parity suites run at 1 *and* 4 pool threads: the kernel programs
//! dispatch through the parallel thread pool while the host-math
//! reference stays a serial loop, so bit-equality here proves the pool's
//! span split never perturbs the optimizer arithmetic.

use std::sync::Arc;

use adama::config::{OptimBackend, OptimizerKind, TrainConfig};
use adama::coordinator::MlpTrainer;
use adama::data::BlobData;
use adama::model::ModelSpec;
use adama::optim::{host_math, AdamA, Hyper, Optimizer, UpdateBackend};
use adama::runtime::Library;
use adama::tensor::Rng;
use adama::{Category, MemoryTracker};

fn tiny_spec(lib: &Arc<Library>) -> ModelSpec {
    let entry = lib.manifest().model_config("tiny").unwrap();
    ModelSpec::from_manifest("tiny", entry).unwrap()
}

fn make_grads(spec: &ModelSpec, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    spec.layers
        .iter()
        .map(|l| (0..l.flat_len).map(|_| rng.normal()).collect())
        .collect()
}

/// AdamA on the kernel path (host executor programs, chunked with
/// zero-padded tails) vs the literal Adam-then-accumulate reference from
/// `host_math`, for N = 1, 2, 4, 8 micro-batches and a serial *and*
/// parallel pool: bit-for-bit equal.
#[test]
fn adama_kernel_path_matches_host_math_bit_for_bit() {
    for pool_threads in [1usize, 4] {
        adama_kernel_path_parity(pool_threads);
    }
}

fn adama_kernel_path_parity(pool_threads: usize) {
    let lib = Library::host_with_threads(pool_threads);
    let spec = tiny_spec(&lib);
    let hyper = Hyper::from_manifest(lib.manifest());
    let chunk = *lib.manifest().chunk_sizes.first().unwrap();
    let (b1, b2) = (hyper.beta1, hyper.beta2);
    let lr = 1e-3f32;

    for n_micro in [1usize, 2, 4, 8] {
        let tracker = MemoryTracker::new();
        let backend = UpdateBackend::kernel(lib.clone(), chunk).unwrap();
        let mut opt = AdamA::new(&spec, hyper, backend, &tracker);

        // reference state driven by host_math directly
        let mut ref_p: Vec<Vec<f32>> = spec
            .layers
            .iter()
            .map(|l| (0..l.flat_len).map(|i| (i % 17) as f32 * 0.05 - 0.4).collect())
            .collect();
        let mut params: Vec<adama::model::LayerParams> = ref_p
            .iter()
            .map(|flat| adama::model::LayerParams { flat: flat.clone() })
            .collect();
        let mut ref_m: Vec<Vec<f32>> =
            spec.layers.iter().map(|l| vec![0.0; l.flat_len]).collect();
        let mut ref_v = ref_m.clone();

        let gscale = 1.0 / n_micro as f32;
        for t in 1..=3u64 {
            opt.begin_minibatch(t).unwrap();
            for k in 0..n_micro {
                let grads = make_grads(&spec, 100 * t + k as u64);
                for (li, g) in grads.iter().enumerate() {
                    opt.accumulate(li, g, gscale).unwrap();
                    // reference: fused decay on the first micro-batch of
                    // the mini-batch, plain accumulate afterwards —
                    // identical scalar expressions to the kernel programs.
                    if k == 0 {
                        host_math::adama_decay_acc(
                            &mut ref_m[li], &mut ref_v[li], g, gscale, b1, b2, b1, b2,
                        );
                    } else {
                        host_math::adama_acc(&mut ref_m[li], &mut ref_v[li], g, gscale, b1, b2);
                    }
                }
            }
            opt.apply(&mut params, lr).unwrap();
            let (bc1, bc2) = hyper.bias_corrections(t);
            for li in 0..spec.layers.len() {
                host_math::adam_update(
                    &mut ref_p[li], &ref_m[li], &ref_v[li], lr, bc1, bc2, hyper.eps,
                );
            }
        }

        for (li, (got, want)) in params.iter().zip(&ref_p).enumerate() {
            assert_eq!(
                got.flat, *want,
                "N={n_micro}, {pool_threads} pool threads: layer {li} params diverged \
                 from host_math reference"
            );
        }
    }
}

/// The kernel path must also agree with a `UpdateBackend::Host` AdamA
/// (the two dispatch arms share the same scalar kernels on the host
/// executor, so equality is exact) — under both a serial and a parallel
/// kernel pool.
#[test]
fn kernel_and_host_update_backends_bitwise_identical() {
    for pool_threads in [1usize, 4] {
        kernel_vs_host_backend_parity(pool_threads);
    }
}

fn kernel_vs_host_backend_parity(pool_threads: usize) {
    let lib = Library::host_with_threads(pool_threads);
    let spec = tiny_spec(&lib);
    let hyper = Hyper::from_manifest(lib.manifest());
    let chunk = *lib.manifest().chunk_sizes.first().unwrap();

    let t1 = MemoryTracker::new();
    let t2 = MemoryTracker::new();
    let mut kernel = AdamA::new(&spec, hyper, UpdateBackend::kernel(lib.clone(), chunk).unwrap(), &t1);
    let mut host = AdamA::new(&spec, hyper, UpdateBackend::host(hyper), &t2);

    let mut pk: Vec<adama::model::LayerParams> = spec
        .layers
        .iter()
        .map(|l| adama::model::LayerParams { flat: vec![0.5; l.flat_len] })
        .collect();
    let mut ph = pk.clone();

    for t in 1..=2u64 {
        kernel.begin_minibatch(t).unwrap();
        host.begin_minibatch(t).unwrap();
        for k in 0..4u64 {
            let grads = make_grads(&spec, 7 * t + k);
            for (li, g) in grads.iter().enumerate() {
                kernel.accumulate(li, g, 0.25).unwrap();
                host.accumulate(li, g, 0.25).unwrap();
            }
        }
        kernel.apply(&mut pk, 1e-3).unwrap();
        host.apply(&mut ph, 1e-3).unwrap();
    }
    for (a, b) in pk.iter().zip(&ph) {
        assert_eq!(a.flat, b.flat, "{pool_threads} pool threads: kernel/host divergence");
    }
}

#[test]
fn null_opt_accumulate_errors_loudly() {
    use adama::optim::NullOpt;
    let mut opt = NullOpt;
    opt.begin_minibatch(1).unwrap();
    let err = opt.accumulate(0, &[0.1, 0.2], 1.0).unwrap_err();
    let msg = format!("{err:?}");
    assert!(msg.contains("external sink"), "unhelpful NullOpt error: {msg}");
}

/// The full MLP trainer runs on the host executor with zero artifacts and
/// actually learns the blob task; the tracker sees every category.
#[test]
fn mlp_trainer_end_to_end_on_host_executor() {
    let lib = Library::host();
    assert_eq!(lib.executor().platform(), "host");
    let cfg = TrainConfig {
        model: "tiny".into(),
        optimizer: OptimizerKind::AdamA,
        backend: OptimBackend::Kernel,
        accum_steps: 4,
        lr: adama::config::LrSchedule::constant(5e-2),
        ..TrainConfig::default()
    };
    let mut trainer = MlpTrainer::new(lib, cfg).unwrap();
    let h = trainer.hyper.clone();
    let mut data = BlobData::new(h.features, h.classes, 5, 6);

    let mut first = 0.0f32;
    let mut last = 0.0f32;
    for step in 0..40 {
        let mbs: Vec<_> = (0..4).map(|_| data.batch(h.microbatch)).collect();
        let loss = trainer.train_step(&mbs).unwrap();
        if step == 0 {
            first = loss;
        }
        last = loss;
    }
    assert!(last < first - 0.2, "MLP must learn on host: {first} -> {last}");

    let eval: Vec<_> = (0..4).map(|_| data.batch(h.microbatch)).collect();
    let (loss, acc) = trainer.eval(&eval).unwrap();
    assert!(loss.is_finite());
    assert!(acc > 0.5, "blob accuracy {acc} too low after training");

    // nonzero measured memory in the core categories
    let tr = trainer.tracker();
    assert!(tr.peak(Category::Weights) > 0);
    assert!(tr.peak(Category::OptimizerStates) > 0);
    assert!(tr.peak(Category::Gradients) > 0);
    assert!(tr.total_peak() > 0);
}

/// SGDM-A (§5 extension) exercises the sgdm_* kernel programs on host.
#[test]
fn sgdma_runs_on_host_kernel_programs() {
    let lib = Library::host();
    let cfg = TrainConfig {
        model: "tiny".into(),
        optimizer: OptimizerKind::SgdmA,
        backend: OptimBackend::Kernel,
        accum_steps: 2,
        lr: adama::config::LrSchedule::constant(5e-2),
        ..TrainConfig::default()
    };
    let mut trainer = MlpTrainer::new(lib, cfg).unwrap();
    let h = trainer.hyper.clone();
    let mut data = BlobData::new(h.features, h.classes, 5, 9);
    let mut first = 0.0f32;
    let mut last = 0.0f32;
    for step in 0..30 {
        let mbs: Vec<_> = (0..2).map(|_| data.batch(h.microbatch)).collect();
        let loss = trainer.train_step(&mbs).unwrap();
        if step == 0 {
            first = loss;
        }
        last = loss;
    }
    assert!(last < first, "SGDM-A on host: {first} -> {last}");
}
