//! `ADAMA_OPT` optimizer-zoo suite (DESIGN: the exec-layer `OptStep`
//! seam): every rule must satisfy the paper's Algorithm-1 invariant —
//! because the gradient fold is linear and `1/M` is a power of two, an
//! M-way micro-batch split is **bit-for-bit identical** to the
//! single-batch update on the summed gradient — and must match a serial
//! scalar oracle re-implemented here from the rule definitions. On top:
//! seam plumbing precedence, dual metering reconciled byte-for-byte
//! against `memmodel::zoo_state_bytes`, cross-config bit parity
//! (threads × backend), and env-driven distributed legs (the CI
//! `optzoo-distributed` job sweeps `ADAMA_OPT` × `ADAMA_RANKS` ×
//! `ADAMA_ASYNC` through these).

use std::sync::Arc;

use adama::collective::{
    run_data_parallel, run_zero1, CollectiveEngine, DpSpec, SyncStrategy, Topology, Zero1Spec,
};
use adama::config::{OptimBackend, OptimizerKind, TrainConfig};
use adama::data::MarkovCorpus;
use adama::memmodel::{paper_shapes, zoo_state_bytes, PaperModel};
use adama::model::{LayerParams, ModelSpec};
use adama::optim::{Hyper, Optimizer, UpdateBackend, ZooOpt};
use adama::runtime::{Library, OptAlgo};
use adama::tensor::Rng;
use adama::{Category, MemoryTracker, Trainer};

mod common;
use common::library;

const DATA_SEED: u64 = 53;

// ---------------------------------------------------------------------------
// helpers
// ---------------------------------------------------------------------------

fn tiny_spec(lib: &Arc<Library>) -> ModelSpec {
    let entry = lib.manifest().model_config("tiny").expect("tiny model in manifest");
    ModelSpec::from_manifest("tiny", entry).unwrap()
}

/// (rows, cols) tuples for every tensor of a spec, `cols == 0` = 1-D —
/// the geometry contract shared with `memmodel::zoo_state_bytes`.
fn shapes_of(spec: &ModelSpec) -> Vec<(u64, u64)> {
    spec.layers
        .iter()
        .flat_map(|l| l.params.iter())
        .map(|v| {
            if v.shape.len() == 2 {
                (v.shape[0] as u64, v.shape[1] as u64)
            } else {
                (v.elements() as u64, 0)
            }
        })
        .collect()
}

fn cfg(workers: usize, n: usize) -> TrainConfig {
    TrainConfig {
        model: "tiny".into(),
        optimizer: OptimizerKind::AdamA,
        backend: OptimBackend::Host,
        accum_steps: n,
        chunk: 16384,
        workers,
        ..TrainConfig::default()
    }
}

/// Rank counts for the distributed legs: `ADAMA_RANKS` or default 2.
fn worlds() -> Vec<usize> {
    match std::env::var("ADAMA_RANKS") {
        Ok(s) if !s.trim().is_empty() => s
            .split(',')
            .map(|t| t.trim().parse::<usize>().expect("ADAMA_RANKS: positive integers"))
            .collect(),
        _ => vec![2],
    }
}

/// Rules to sweep: a set `ADAMA_OPT` narrows the suite to that rule (the
/// CI matrix runs one rule per leg); unset sweeps all four.
fn algos() -> Vec<OptAlgo> {
    match OptAlgo::from_env().expect("ADAMA_OPT must parse") {
        Some(a) => vec![a],
        None => OptAlgo::ALL.to_vec(),
    }
}

fn param_bits(params: &[Vec<f32>]) -> Vec<Vec<u32>> {
    params.iter().map(|l| l.iter().map(|x| x.to_bits()).collect()).collect()
}

fn flat_bits(params: &[LayerParams]) -> Vec<Vec<u32>> {
    params.iter().map(|l| l.flat.iter().map(|x| x.to_bits()).collect()).collect()
}

fn loss_bits(losses: &[f32]) -> Vec<u32> {
    losses.iter().map(|x| x.to_bits()).collect()
}

fn mk_params(spec: &ModelSpec, rng: &mut Rng) -> Vec<LayerParams> {
    spec.layers
        .iter()
        .map(|l| LayerParams { flat: (0..l.flat_len).map(|_| 0.1 * rng.normal()).collect() })
        .collect()
}

fn rand_grads(spec: &ModelSpec, rng: &mut Rng) -> Vec<Vec<f32>> {
    spec.layers
        .iter()
        .map(|l| (0..l.flat_len).map(|_| rng.normal()).collect())
        .collect()
}

// ---------------------------------------------------------------------------
// serial scalar oracle — an independent re-implementation of each rule
// from its definition (no shared code with optim::zoo beyond Hyper)
// ---------------------------------------------------------------------------

struct OracleTensor {
    range: std::ops::Range<usize>,
    rows: usize,
    cols: usize,
    bufs: Vec<Vec<f32>>,
}

struct Oracle {
    algo: OptAlgo,
    hy: Hyper,
    tensors: Vec<Vec<OracleTensor>>,
}

impl Oracle {
    fn new(algo: OptAlgo, spec: &ModelSpec, hy: Hyper) -> Self {
        let tensors = spec
            .layers
            .iter()
            .map(|l| {
                l.params
                    .iter()
                    .map(|p| {
                        let (rows, cols) = if p.shape.len() == 2 {
                            (p.shape[0], p.shape[1])
                        } else {
                            (p.elements(), 0)
                        };
                        let bufs =
                            algo.state_lens(rows, cols).into_iter().map(|n| vec![0.0; n]).collect();
                        OracleTensor { range: p.range.clone(), rows, cols, bufs }
                    })
                    .collect()
            })
            .collect();
        Self { algo, hy, tensors }
    }

    /// One mini-batch update from the accumulated mean gradient `acc`.
    fn step(&mut self, params: &mut [LayerParams], acc: &[Vec<f32>], t: u64, lr: f32) {
        const EPS1: f32 = 1e-30;
        let (b1, b2a, eps) = (self.hy.beta1, self.hy.beta2, self.hy.eps);
        let (bc1, bc2) = self.hy.bias_corrections(t);
        for (layer, slots) in self.tensors.iter_mut().enumerate() {
            for s in slots.iter_mut() {
                let p = &mut params[layer].flat[s.range.clone()];
                let g = &acc[layer][s.range.clone()];
                let (rows, cols) = (s.rows, s.cols);
                match self.algo {
                    OptAlgo::Adam => {
                        let (m, v) = s.bufs.split_at_mut(1);
                        let (m, v) = (&mut m[0], &mut v[0]);
                        for i in 0..p.len() {
                            m[i] = b1 * m[i] + (1.0 - b1) * g[i];
                            v[i] = b2a * v[i] + (1.0 - b2a) * g[i] * g[i];
                            p[i] -= lr * (m[i] / bc1) / ((v[i] / bc2).sqrt() + eps);
                        }
                    }
                    OptAlgo::Adafactor => {
                        let b2 = 1.0 - (t as f32).powf(-0.8).min(1.0 - b2a);
                        if cols > 0 {
                            let (rv, cv) = s.bufs.split_at_mut(1);
                            let (rv, cv) = (&mut rv[0], &mut cv[0]);
                            for i in 0..rows {
                                let mean = (0..cols)
                                    .map(|j| g[i * cols + j] * g[i * cols + j] + EPS1)
                                    .sum::<f32>()
                                    / cols as f32;
                                rv[i] = b2 * rv[i] + (1.0 - b2) * mean;
                            }
                            for j in 0..cols {
                                let mean = (0..rows)
                                    .map(|i| g[i * cols + j] * g[i * cols + j] + EPS1)
                                    .sum::<f32>()
                                    / rows as f32;
                                cv[j] = b2 * cv[j] + (1.0 - b2) * mean;
                            }
                            let row_mean = rv.iter().sum::<f32>().max(EPS1) / rows as f32;
                            for i in 0..rows {
                                let rfac = rv[i] / row_mean;
                                for j in 0..cols {
                                    p[i * cols + j] -= lr * g[i * cols + j]
                                        / ((rfac * cv[j]).sqrt() + eps);
                                }
                            }
                        } else {
                            let v = &mut s.bufs[0];
                            for i in 0..p.len() {
                                v[i] = b2 * v[i] + (1.0 - b2) * (g[i] * g[i] + EPS1);
                                p[i] -= lr * g[i] / ((1.0 * v[i]).sqrt() + eps);
                            }
                        }
                    }
                    OptAlgo::Sm3 => {
                        if cols > 0 {
                            let (rv, cv) = s.bufs.split_at_mut(1);
                            let (rv, cv) = (&mut rv[0], &mut cv[0]);
                            let mut new_rows = vec![0.0f32; rows];
                            let mut new_cols = vec![0.0f32; cols];
                            for i in 0..rows {
                                for j in 0..cols {
                                    let gij = g[i * cols + j];
                                    let nu = rv[i].min(cv[j]) + gij * gij;
                                    p[i * cols + j] -= lr * gij / (nu.sqrt() + eps);
                                    new_rows[i] = new_rows[i].max(nu);
                                    new_cols[j] = new_cols[j].max(nu);
                                }
                            }
                            rv.copy_from_slice(&new_rows);
                            cv.copy_from_slice(&new_cols);
                        } else {
                            let v = &mut s.bufs[0];
                            for i in 0..p.len() {
                                let nu = f32::INFINITY.min(v[i]) + g[i] * g[i];
                                p[i] -= lr * g[i] / (nu.sqrt() + eps);
                                v[i] = nu;
                            }
                        }
                    }
                    OptAlgo::AdamMini => {
                        let (m, vb) = s.bufs.split_at_mut(1);
                        let (m, vb) = (&mut m[0], &mut vb[0]);
                        for i in 0..m.len() {
                            m[i] = b1 * m[i] + (1.0 - b1) * g[i];
                        }
                        let blocks: Vec<(usize, usize)> = if cols > 0 {
                            (0..rows).map(|i| (i * cols, cols)).collect()
                        } else {
                            vec![(0, p.len())]
                        };
                        for (b, &(off, len)) in blocks.iter().enumerate() {
                            let gsq = g[off..off + len].iter().map(|x| x * x).sum::<f32>()
                                / len.max(1) as f32;
                            vb[b] = b2a * vb[b] + (1.0 - b2a) * gsq;
                            let scale = lr / ((vb[b] / bc2).sqrt() + eps);
                            for i in off..off + len {
                                p[i] -= scale * (m[i] / bc1);
                            }
                        }
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// accumulation parity — the tentpole invariant, per rule × backend
// ---------------------------------------------------------------------------

#[test]
fn accumulation_parity_bit_identical_for_every_rule_and_backend() {
    // For M ∈ {1, 2, 4, 8}: folding M micro-batch gradients at gscale
    // 1/M must be bit-identical to one fold of the (serially) summed
    // gradient — and both must match the scalar oracle on the mean.
    let lib = library();
    let spec = tiny_spec(&lib);
    let hy = Hyper::from_manifest(lib.manifest());
    let lr = 0.01f32;
    for algo in algos() {
        for kernel in [false, true] {
            let mk_backend = || -> UpdateBackend {
                if kernel {
                    UpdateBackend::kernel(lib.clone(), 16384).unwrap()
                } else {
                    UpdateBackend::host(hy)
                }
            };
            for m in [1usize, 2, 4, 8] {
                let tag = format!("{} kernel={kernel} M={m}", algo.name());
                let tracker = MemoryTracker::new();
                let mut split =
                    ZooOpt::new(algo, &spec, hy, mk_backend(), mk_backend(), true, &tracker);
                let mut fused =
                    ZooOpt::new(algo, &spec, hy, mk_backend(), mk_backend(), true, &tracker);
                let mut oracle = Oracle::new(algo, &spec, hy);

                let mut rng = Rng::new(100 + m as u64);
                let mut p_split = mk_params(&spec, &mut rng);
                let mut p_fused: Vec<LayerParams> =
                    p_split.iter().map(|l| LayerParams { flat: l.flat.clone() }).collect();
                let mut p_oracle: Vec<LayerParams> =
                    p_split.iter().map(|l| LayerParams { flat: l.flat.clone() }).collect();
                let gscale = 1.0 / m as f32;

                for t in 1..=3u64 {
                    let micros: Vec<Vec<Vec<f32>>> =
                        (0..m).map(|_| rand_grads(&spec, &mut rng)).collect();
                    // serial left-fold sum, the order the split fold uses
                    let mut gsum = micros[0].clone();
                    for g in &micros[1..] {
                        for (s, gl) in gsum.iter_mut().zip(g) {
                            for (a, b) in s.iter_mut().zip(gl) {
                                *a += *b;
                            }
                        }
                    }

                    split.begin_minibatch(t).unwrap();
                    for g in &micros {
                        for (l, gl) in g.iter().enumerate() {
                            split.accumulate(l, gl, gscale).unwrap();
                        }
                    }
                    split.apply(&mut p_split, lr).unwrap();

                    fused.begin_minibatch(t).unwrap();
                    for (l, gl) in gsum.iter().enumerate() {
                        fused.accumulate(l, gl, gscale).unwrap();
                    }
                    fused.apply(&mut p_fused, lr).unwrap();

                    assert_eq!(
                        flat_bits(&p_split),
                        flat_bits(&p_fused),
                        "{tag} t={t}: M-way split diverged from fused fold"
                    );

                    // oracle on the exact mean (power-of-two scaling is
                    // exact, so this is the same accumulator value)
                    let mean: Vec<Vec<f32>> = gsum
                        .iter()
                        .map(|l| l.iter().map(|x| x * gscale).collect())
                        .collect();
                    oracle.step(&mut p_oracle, &mean, t, lr);
                    assert_eq!(
                        flat_bits(&p_split),
                        flat_bits(&p_oracle),
                        "{tag} t={t}: diverged from the serial scalar oracle"
                    );
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// seam plumbing + metering reconciliation (memmodel twin)
// ---------------------------------------------------------------------------

#[test]
fn seam_build_reconciles_exactly_with_memmodel() {
    let lib = library();
    let spec = tiny_spec(&lib);
    let shapes = shapes_of(&spec);
    for algo in algos() {
        let zlib = lib.fork_with_opt(Some(algo));
        let mut t = Trainer::new(zlib, cfg(1, 4)).unwrap();
        let h = t.spec().hyper.clone();
        let mut c = MarkovCorpus::new(h.vocab, DATA_SEED, 1);
        t.train_step(&c.minibatch(4, h.microbatch, h.seq)).unwrap();
        // state-resident: accumulator is optimizer state, no persistent
        // gradient memory — measured == analytic, byte for byte
        let analytic = zoo_state_bytes(algo, &shapes, true);
        assert_eq!(t.optimizer_mut().state_bytes() as u64, analytic, "{}", algo.name());
        assert_eq!(
            t.tracker().peak(Category::OptimizerStates) as u64,
            analytic,
            "{}: tracker ledger",
            algo.name()
        );
        assert_eq!(t.optimizer_mut().persistent_grad_bytes(), 0, "{}", algo.name());
    }
}

#[test]
fn ga_build_reconciles_exactly_with_memmodel() {
    // cfg-selected zoo kinds keep the GA-style comparator metering: the
    // accumulator is persistent *gradient* memory, excluded from state.
    let lib = library().fork_with_opt(None); // shed any ambient ADAMA_OPT
    let spec = tiny_spec(&lib);
    let shapes = shapes_of(&spec);
    let p_bytes = (spec.total_params() * 4) as u64;
    for (kind, algo) in [
        (OptimizerKind::AdamGA, OptAlgo::Adam),
        (OptimizerKind::Adafactor, OptAlgo::Adafactor),
        (OptimizerKind::Sm3, OptAlgo::Sm3),
        (OptimizerKind::AdamMini, OptAlgo::AdamMini),
    ] {
        let mut c = cfg(1, 4);
        c.optimizer = kind;
        let mut t = Trainer::new(lib.clone(), c).unwrap();
        let analytic = zoo_state_bytes(algo, &shapes, false);
        assert_eq!(t.optimizer_mut().state_bytes() as u64, analytic, "{kind:?}");
        assert_eq!(t.optimizer_mut().persistent_grad_bytes() as u64, p_bytes, "{kind:?}");
        assert_eq!(t.tracker().peak(Category::OptimizerStates) as u64, analytic, "{kind:?}");
    }
}

#[test]
fn paper_scale_projection_matches_closed_forms() {
    // satellite 4, projection half: the paper-scale analytic formula
    // must equal an independently-summed closed form per rule.
    let m = PaperModel::bert_large();
    let shapes = paper_shapes(&m);
    let p: u64 = shapes.iter().map(|&(r, c)| r * c.max(1)).sum();
    let factored: u64 = shapes
        .iter()
        .map(|&(r, c)| if c > 0 { r + c } else { r })
        .sum();
    let row_blocks: u64 = shapes.iter().map(|&(r, c)| if c > 0 { r } else { 1 }).sum();
    assert_eq!(zoo_state_bytes(OptAlgo::Adam, &shapes, false), 8 * p);
    assert_eq!(zoo_state_bytes(OptAlgo::Adafactor, &shapes, false), 4 * factored);
    assert_eq!(zoo_state_bytes(OptAlgo::Sm3, &shapes, false), 4 * factored);
    assert_eq!(zoo_state_bytes(OptAlgo::AdamMini, &shapes, false), 4 * (p + row_blocks));
    // the state-resident seam adds exactly one P-float accumulator
    for algo in OptAlgo::ALL {
        assert_eq!(
            zoo_state_bytes(algo, &shapes, true) - zoo_state_bytes(algo, &shapes, false),
            4 * p
        );
    }
}

#[test]
fn spec_with_opt_beats_ambient_seam() {
    // precedence: fork_with_opt replaces (or clears) whatever the library
    // carries — the distributed spec `with_opt` routes through this.
    let lib = library().fork_with_opt(Some(OptAlgo::Sm3));
    assert_eq!(lib.executor().opt_algo(), Some(OptAlgo::Sm3));
    let re = lib.fork_with_opt(Some(OptAlgo::Adafactor));
    assert_eq!(re.executor().opt_algo(), Some(OptAlgo::Adafactor));
    let cleared = lib.fork_with_opt(None);
    assert_eq!(cleared.executor().opt_algo(), None);
    // rank forks inherit the selection
    let forked = re.fork_with_threads(2);
    assert_eq!(forked.executor().opt_algo(), Some(OptAlgo::Adafactor));
}

// ---------------------------------------------------------------------------
// cross-config bit parity: threads × backend through the full trainer
// ---------------------------------------------------------------------------

#[test]
fn zoo_training_bits_survive_threads_and_backend() {
    let lib = library();
    for algo in algos() {
        let run = |threads: usize, backend: OptimBackend| -> (Vec<u32>, Vec<Vec<u32>>) {
            let zlib = lib.fork_with_opt(Some(algo)).fork_with_threads(threads);
            let mut c = cfg(1, 2);
            c.backend = backend;
            let mut t = Trainer::new(zlib, c).unwrap();
            let h = t.spec().hyper.clone();
            let mut corpus = MarkovCorpus::new(h.vocab, DATA_SEED, 1);
            let mut losses = Vec::new();
            for _ in 0..3 {
                let stats = t.train_step(&corpus.minibatch(2, h.microbatch, h.seq)).unwrap();
                losses.push(stats.loss);
            }
            let params: Vec<Vec<f32>> = t.params().iter().map(|l| l.flat.clone()).collect();
            (loss_bits(&losses), param_bits(&params))
        };
        let oracle = run(1, OptimBackend::Host);
        for (threads, backend) in
            [(4, OptimBackend::Host), (1, OptimBackend::Kernel), (4, OptimBackend::Kernel)]
        {
            let got = run(threads, backend);
            assert_eq!(
                got, oracle,
                "{} threads={threads} {backend:?}: bits changed",
                algo.name()
            );
        }
    }
}

// ---------------------------------------------------------------------------
// distributed legs: DP + ZeRO-S1 through every engine, ledger-exact
// ---------------------------------------------------------------------------

#[test]
fn dp_zoo_engines_match_serial_simulator_bit_for_bit() {
    let lib = library();
    for algo in algos() {
        for m in worlds() {
            let dp = |engine| {
                run_data_parallel(
                    lib.clone(),
                    DpSpec::new(cfg(m, 2), SyncStrategy::Gradients, 2, DATA_SEED)
                        .with_opt(algo)
                        .with_engine(engine)
                        .with_topology(Topology::Ring),
                )
                .unwrap_or_else(|e| panic!("dp zoo {} M={m}: {e:?}", algo.name()))
            };
            let oracle = dp(CollectiveEngine::Serial);
            for engine in [CollectiveEngine::Channel, CollectiveEngine::Fabric] {
                let got = dp(engine);
                let tag = format!("dp zoo {} {} M={m}", algo.name(), engine.name());
                assert_eq!(loss_bits(&got.losses), loss_bits(&oracle.losses), "{tag}");
                assert_eq!(
                    param_bits(&got.final_params),
                    param_bits(&oracle.final_params),
                    "{tag}"
                );
                assert_eq!(got.comm_bytes, oracle.comm_bytes, "{tag}: wire ledger");
                assert_eq!(got.comm_ops, oracle.comm_ops, "{tag}: op ledger");
                assert_eq!(
                    got.per_rank_memory, oracle.per_rank_memory,
                    "{tag}: MemStats ledger"
                );
            }
        }
    }
}

#[test]
fn zero1_zoo_engines_match_serial_simulator_bit_for_bit() {
    let lib = library();
    for algo in algos() {
        for m in worlds().into_iter().filter(|&m| m >= 2) {
            let z1 = |engine| {
                run_zero1(
                    lib.clone(),
                    Zero1Spec::new(cfg(m, 2), 2, DATA_SEED)
                        .with_opt(algo)
                        .with_engine(engine)
                        .with_topology(Topology::Ring),
                )
                .unwrap_or_else(|e| panic!("zero1 zoo {} M={m}: {e:?}", algo.name()))
            };
            let oracle = z1(CollectiveEngine::Serial);
            for engine in [CollectiveEngine::Channel, CollectiveEngine::Fabric] {
                let got = z1(engine);
                let tag = format!("zero1 zoo {} {} M={m}", algo.name(), engine.name());
                assert_eq!(loss_bits(&got.losses), loss_bits(&oracle.losses), "{tag}");
                assert_eq!(
                    param_bits(&got.final_params),
                    param_bits(&oracle.final_params),
                    "{tag}"
                );
                assert_eq!(got.comm_bytes, oracle.comm_bytes, "{tag}: wire ledger");
                assert_eq!(got.comm_ops, oracle.comm_ops, "{tag}: op ledger");
                assert_eq!(
                    got.per_rank_memory, oracle.per_rank_memory,
                    "{tag}: MemStats ledger"
                );
            }
        }
    }
}

#[test]
fn zero1_zoo_async_issue_matches_sync_bit_for_bit() {
    // the async fabric path composes with the zoo's sharded accumulator:
    // ticketed reduce-scatters change scheduling only.
    let lib = library();
    for algo in algos() {
        for m in worlds().into_iter().filter(|&m| m >= 2) {
            let z = |async_issue: bool, bucket: usize| {
                run_zero1(
                    lib.clone(),
                    Zero1Spec::new(cfg(m, 2), 2, DATA_SEED)
                        .with_opt(algo)
                        .with_engine(CollectiveEngine::Fabric)
                        .with_topology(Topology::Ring)
                        .with_async(async_issue)
                        .with_bucket_bytes(bucket),
                )
                .unwrap_or_else(|e| panic!("zero1 zoo async {} M={m}: {e:?}", algo.name()))
            };
            let sync = z(false, 0);
            for bucket in [0usize, 4 << 10] {
                let got = z(true, bucket);
                let tag = format!("zero1 zoo async {} M={m} bucket={bucket}", algo.name());
                assert_eq!(loss_bits(&got.losses), loss_bits(&sync.losses), "{tag}");
                assert_eq!(
                    param_bits(&got.final_params),
                    param_bits(&sync.final_params),
                    "{tag}"
                );
                assert_eq!(got.comm_bytes, sync.comm_bytes, "{tag}: wire ledger");
                assert_eq!(got.comm_ops, sync.comm_ops, "{tag}: op ledger");
            }
        }
    }
}

#[test]
fn dp_zoo_rejects_state_sync_strategies() {
    // (m, v) all-reduce (Eq. 7-8) and per-micro-batch gradient sync are
    // AdamA-shaped; the zoo must refuse rather than silently diverge.
    let lib = library();
    for sync in [SyncStrategy::OptimizerStates, SyncStrategy::GradPerMicrobatch] {
        let err = run_data_parallel(
            lib.clone(),
            DpSpec::new(cfg(2, 2), sync, 1, DATA_SEED).with_opt(OptAlgo::Adafactor),
        );
        let msg = format!("{:?}", err.unwrap_err());
        assert!(msg.contains("AdamA"), "{sync:?}: {msg}");
    }
}

// ---------------------------------------------------------------------------
// end-to-end sanity: every rule actually trains
// ---------------------------------------------------------------------------

#[test]
fn every_rule_reduces_tiny_lm_loss() {
    let lib = library();
    for algo in algos() {
        let zlib = lib.fork_with_opt(Some(algo));
        let mut t = Trainer::new(zlib, cfg(1, 2)).unwrap();
        let h = t.spec().hyper.clone();
        let mut corpus = MarkovCorpus::new(h.vocab, DATA_SEED, 1);
        let eval_set = corpus.minibatch(8, h.microbatch, h.seq);
        let (loss0, _) = t.eval(&eval_set).unwrap();
        for _ in 0..12 {
            t.train_step(&corpus.minibatch(2, h.microbatch, h.seq)).unwrap();
        }
        let (loss1, _) = t.eval(&eval_set).unwrap();
        assert!(
            loss1 < loss0,
            "{}: loss {loss1} did not improve on {loss0}",
            algo.name()
        );
    }
}
