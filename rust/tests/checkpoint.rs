//! Crash-safe checkpoint/resume suite: `ADAMACK1`/`ADAMACK2` file-format
//! strictness, single-rank save/resume, world checkpoints for the DP and
//! ZeRO-S1 runners (sync + async issue), rotation/retention, world
//! resharding, and the deterministic fault-injection drills (`fault_*`;
//! the CI `crash-recovery` job re-runs those with `ADAMA_FAULT` exported
//! so the env-knob path is exercised end to end).
//!
//! The headline invariant: kill a rank mid-run, auto-recover from the
//! newest valid world checkpoint, and finish with losses, parameters and
//! the comm ledger bit-equal to a run that was never interrupted.

use std::path::PathBuf;

use adama::collective::{
    run_data_parallel, run_zero1, CollectiveEngine, DpSpec, FaultPlan, PeerDeath, SyncStrategy,
    Zero1Spec,
};
use adama::config::{OptimBackend, OptimizerKind, TrainConfig};
use adama::coordinator::{checkpoint as ckdisc, CheckpointPolicy};
use adama::data::MarkovCorpus;
use adama::model::checkpoint as ck1;
use adama::runtime::OptAlgo;
use adama::Trainer;

mod common;
use common::library;

const DATA_SEED: u64 = 77;

fn cfg(opt: OptimizerKind, workers: usize, n: usize) -> TrainConfig {
    TrainConfig {
        model: "tiny".into(),
        optimizer: opt,
        backend: OptimBackend::Host,
        accum_steps: n,
        chunk: 16384,
        workers,
        ..TrainConfig::default()
    }
}

/// Two-rank DP spec over the state all-reduce flow (Eq. 7-8).
fn dp_state(steps: u64) -> DpSpec {
    DpSpec::new(cfg(OptimizerKind::AdamA, 2, 2), SyncStrategy::OptimizerStates, steps, DATA_SEED)
}

/// Two-rank DP spec over the gradient all-reduce flow (zoo rules).
fn dp_grad(steps: u64) -> DpSpec {
    DpSpec::new(cfg(OptimizerKind::AdamA, 2, 2), SyncStrategy::Gradients, steps, DATA_SEED)
}

fn z1(opt: OptimizerKind, workers: usize, steps: u64) -> Zero1Spec {
    Zero1Spec::new(cfg(opt, workers, 2), steps, DATA_SEED)
}

/// Fresh scratch directory, unique per test tag and process (tests run
/// concurrently and CI runs this binary more than once). Any stale
/// leftover from a previous crashed run is removed up front.
fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("adama_ckpt_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

fn policy(every: u64, keep: usize) -> CheckpointPolicy {
    CheckpointPolicy { every_k_steps: every, keep_last_n: keep }
}

fn bits(params: &[Vec<f32>]) -> Vec<Vec<u32>> {
    params.iter().map(|l| l.iter().map(|x| x.to_bits()).collect()).collect()
}

fn loss_bits(losses: &[f32]) -> Vec<u32> {
    losses.iter().map(|x| x.to_bits()).collect()
}

// ---------------------------------------------------------------------------
// file formats: ADAMACK1 (params-only) and the single-rank ADAMACK2 path
// ---------------------------------------------------------------------------

#[test]
fn adamack1_save_is_atomic_and_load_is_strict() {
    let lib = library();
    let mut t = Trainer::new(lib, cfg(OptimizerKind::AdamA, 1, 2)).unwrap();
    let h = t.spec().hyper.clone();
    let mut c = MarkovCorpus::new(h.vocab, DATA_SEED, 1);
    t.train_step(&c.minibatch(2, h.microbatch, h.seq)).unwrap();

    let dir = scratch("ack1");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("params.ckpt");
    ck1::save(&path, t.spec(), t.params()).unwrap();
    // atomic publish: the canonical name exists, the staging name does not
    assert!(path.exists());
    assert!(!dir.join("params.ckpt.tmp").exists());

    let loaded = ck1::load(&path, t.spec()).unwrap();
    let orig: Vec<Vec<f32>> = t.params().iter().map(|p| p.flat.clone()).collect();
    let round: Vec<Vec<f32>> = loaded.iter().map(|p| p.flat.clone()).collect();
    assert_eq!(bits(&orig), bits(&round));

    // trailing garbage is refused, not ignored
    let mut blob = std::fs::read(&path).unwrap();
    blob.push(0u8);
    std::fs::write(&path, &blob).unwrap();
    let err = format!("{:?}", ck1::load(&path, t.spec()).unwrap_err());
    assert!(err.contains("trailing garbage"), "{err}");

    // a truncated file names the layer and byte offset where it cut off
    blob.truncate(blob.len() / 2);
    std::fs::write(&path, &blob).unwrap();
    let err = format!("{:?}", ck1::load(&path, t.spec()).unwrap_err());
    assert!(err.contains("byte offset"), "{err}");

    // a foreign magic is named, pointing at the ADAMACK2 container
    std::fs::write(&path, b"NOTACKPT________").unwrap();
    let err = format!("{:?}", ck1::load(&path, t.spec()).unwrap_err());
    assert!(err.contains("ADAMACK1"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn single_rank_resume_is_bit_exact() {
    // straight N steps vs (train, save, resume in a new trainer, finish):
    // params must agree to the bit, for the flagship AdamA optimizer and
    // a zoo rule routed through the exec-layer seam.
    let base = library();
    for (tag, zoo) in [("adama", None), ("adafactor", Some(OptAlgo::Adafactor))] {
        let lib = match zoo {
            Some(a) => base.fork_with_opt(Some(a)),
            None => base.clone(),
        };
        let c = cfg(OptimizerKind::AdamA, 1, 2);
        let h = lib.manifest().model_config("tiny").unwrap().model.clone();

        let mut straight = Trainer::new(lib.clone(), c.clone()).unwrap();
        let mut sc = MarkovCorpus::new(h.vocab, DATA_SEED, 1);
        for _ in 0..5 {
            straight.train_step(&sc.minibatch(2, h.microbatch, h.seq)).unwrap();
        }

        let dir = scratch(&format!("single_{tag}"));
        std::fs::create_dir_all(&dir).unwrap();
        let mut t = Trainer::new(lib.clone(), c.clone()).unwrap();
        let mut tc = MarkovCorpus::new(h.vocab, DATA_SEED, 1);
        for _ in 0..3 {
            t.train_step(&tc.minibatch(2, h.microbatch, h.seq)).unwrap();
        }
        let file = ckdisc::step_file(&dir, t.step());
        t.save_state(&file, &[tc.rng().clone()]).unwrap();
        drop(t);

        let (mut r, rngs) = Trainer::resume(lib.clone(), c.clone(), &file).unwrap();
        assert_eq!(r.step(), 3, "{tag}");
        let mut rc = MarkovCorpus::new(h.vocab, DATA_SEED, 1);
        rc.set_rng(rngs[0].clone());
        for _ in 0..2 {
            r.train_step(&rc.minibatch(2, h.microbatch, h.seq)).unwrap();
        }

        let a: Vec<Vec<f32>> = straight.params().iter().map(|p| p.flat.clone()).collect();
        let b: Vec<Vec<f32>> = r.params().iter().map(|p| p.flat.clone()).collect();
        assert_eq!(bits(&a), bits(&b), "{tag}");
        std::fs::remove_dir_all(&dir).ok();
    }
}

#[test]
fn single_rank_rotation_keeps_newest_n() {
    let lib = library();
    let mut t = Trainer::new(lib, cfg(OptimizerKind::AdamA, 1, 2)).unwrap();
    let h = t.spec().hyper.clone();
    let mut c = MarkovCorpus::new(h.vocab, DATA_SEED, 1);
    let dir = scratch("rotate");
    let pol = policy(1, 2);
    for step in 1..=4u64 {
        t.train_step(&c.minibatch(2, h.microbatch, h.seq)).unwrap();
        let wrote = t.maybe_checkpoint(&dir, &pol, &[c.rng().clone()]).unwrap();
        assert_eq!(wrote.is_some(), pol.due(step));
    }
    let listed = ckdisc::list_steps(&dir).unwrap();
    let steps: Vec<u64> = listed.into_iter().map(|(s, _)| s).collect();
    assert_eq!(steps, vec![3, 4], "rotation keeps only the newest keep_last_n");
    for entry in std::fs::read_dir(&dir).unwrap() {
        let name = entry.unwrap().file_name();
        assert!(!name.to_string_lossy().ends_with(".tmp"), "staging straggler: {name:?}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------------
// world checkpoints: DP and ZeRO-S1 resume parity, sync and async issue
// ---------------------------------------------------------------------------

#[test]
fn dp_resume_matches_straight_run_sync_and_async() {
    let lib = library();
    for async_issue in [false, true] {
        let tag = format!("async={async_issue}");
        let spec = dp_state(4).with_async(async_issue);
        let straight = run_data_parallel(lib.clone(), spec).unwrap();
        assert_eq!(straight.resumed_from, None);

        let dir = scratch(&format!("dp_resume_{}", async_issue as u8));
        let first = dp_state(2).with_async(async_issue).with_checkpoint(&dir, policy(2, 2));
        run_data_parallel(lib.clone(), first).unwrap();
        let second = dp_state(4).with_async(async_issue).with_checkpoint(&dir, policy(2, 2));
        let resumed = run_data_parallel(lib.clone(), second.with_resume()).unwrap();

        assert_eq!(resumed.resumed_from, Some(2), "{tag}");
        assert_eq!(loss_bits(&resumed.losses), loss_bits(&straight.losses), "{tag}");
        assert_eq!(bits(&resumed.final_params), bits(&straight.final_params), "{tag}");
        // the barrier-only checkpoint protocol must be ledger-invisible
        assert_eq!(resumed.comm_bytes, straight.comm_bytes, "{tag}");
        assert_eq!(resumed.comm_ops, straight.comm_ops, "{tag}");
        std::fs::remove_dir_all(&dir).ok();
    }
}

#[test]
fn dp_zoo_resume_matches_straight_run() {
    // a zoo rule at the exec seam rides the generic TrainState round-trip
    let lib = library();
    let mk = |steps: u64| dp_grad(steps).with_opt(OptAlgo::Adafactor).with_async(false);
    let straight = run_data_parallel(lib.clone(), mk(4)).unwrap();

    let dir = scratch("dp_zoo");
    run_data_parallel(lib.clone(), mk(2).with_checkpoint(&dir, policy(2, 2))).unwrap();
    let second = mk(4).with_checkpoint(&dir, policy(2, 2)).with_resume();
    let resumed = run_data_parallel(lib.clone(), second).unwrap();

    assert_eq!(resumed.resumed_from, Some(2));
    assert_eq!(loss_bits(&resumed.losses), loss_bits(&straight.losses));
    assert_eq!(bits(&resumed.final_params), bits(&straight.final_params));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn zero1_resume_matches_straight_run_sync_and_async() {
    // ZeRO-S1 + AdamA: the sharded (m, v) halves round-trip through the
    // per-rank shard files and land back bit-identical
    let lib = library();
    for async_issue in [false, true] {
        let tag = format!("async={async_issue}");
        let mk = |steps: u64| z1(OptimizerKind::AdamA, 2, steps).with_async(async_issue);
        let straight = run_zero1(lib.clone(), mk(4)).unwrap();

        let dir = scratch(&format!("z1_resume_{}", async_issue as u8));
        run_zero1(lib.clone(), mk(2).with_checkpoint(&dir, policy(2, 2))).unwrap();
        let second = mk(4).with_checkpoint(&dir, policy(2, 2)).with_resume();
        let resumed = run_zero1(lib.clone(), second).unwrap();

        assert_eq!(resumed.resumed_from, Some(2), "{tag}");
        assert_eq!(loss_bits(&resumed.losses), loss_bits(&straight.losses), "{tag}");
        assert_eq!(bits(&resumed.final_params), bits(&straight.final_params), "{tag}");
        assert_eq!(resumed.comm_bytes, straight.comm_bytes, "{tag}");
        assert_eq!(resumed.comm_ops, straight.comm_ops, "{tag}");
        std::fs::remove_dir_all(&dir).ok();
    }
}

#[test]
fn zero1_ga_resume_matches_straight_run() {
    let lib = library();
    let mk = |steps: u64| z1(OptimizerKind::AdamGA, 2, steps).with_async(false);
    let straight = run_zero1(lib.clone(), mk(4)).unwrap();

    let dir = scratch("z1_ga");
    run_zero1(lib.clone(), mk(2).with_checkpoint(&dir, policy(2, 2))).unwrap();
    let second = mk(4).with_checkpoint(&dir, policy(2, 2)).with_resume();
    let resumed = run_zero1(lib.clone(), second).unwrap();

    assert_eq!(resumed.resumed_from, Some(2));
    assert_eq!(loss_bits(&resumed.losses), loss_bits(&straight.losses));
    assert_eq!(bits(&resumed.final_params), bits(&straight.final_params));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn zero1_zoo_resume_matches_straight_run() {
    // both zoo shard shapes: Adam shards (m, v) like the flagship flow,
    // SM3 keeps replicated per-rank stats
    let lib = library();
    for algo in [OptAlgo::Adam, OptAlgo::Sm3] {
        let name = algo.name();
        let mk = |steps: u64| z1(OptimizerKind::AdamA, 2, steps).with_opt(algo).with_async(false);
        let straight = run_zero1(lib.clone(), mk(4)).unwrap();

        let dir = scratch(&format!("z1_zoo_{name}"));
        run_zero1(lib.clone(), mk(2).with_checkpoint(&dir, policy(2, 2))).unwrap();
        let second = mk(4).with_checkpoint(&dir, policy(2, 2)).with_resume();
        let resumed = run_zero1(lib.clone(), second).unwrap();

        assert_eq!(resumed.resumed_from, Some(2), "{name}");
        assert_eq!(loss_bits(&resumed.losses), loss_bits(&straight.losses), "{name}");
        assert_eq!(bits(&resumed.final_params), bits(&straight.final_params), "{name}");
        std::fs::remove_dir_all(&dir).ok();
    }
}

#[test]
fn zero1_resume_reshards_to_a_wider_world() {
    // a world-2 checkpoint resumed at world 3: the (m, v) shards are
    // unsharded to full layers and re-cut for the new world. The blend of
    // old data cursors (ranks 0, 1) and a fresh stream (rank 2) is fully
    // deterministic, so two identical resumes must agree to the bit.
    let lib = library();
    let dir = scratch("z1_reshard");
    let seed = z1(OptimizerKind::AdamA, 2, 2).with_async(false);
    run_zero1(lib.clone(), seed.with_checkpoint(&dir, policy(2, 2))).unwrap();

    // the resume cadence (8) never fires in 4 steps: read-only resumes
    let wider = || {
        z1(OptimizerKind::AdamA, 3, 4)
            .with_async(false)
            .with_checkpoint(&dir, policy(8, 2))
            .with_resume()
    };
    let a = run_zero1(lib.clone(), wider()).unwrap();
    let b = run_zero1(lib.clone(), wider()).unwrap();
    assert_eq!(a.resumed_from, Some(2));
    assert_eq!(b.resumed_from, Some(2));
    assert_eq!(a.losses.len(), 4);
    assert_eq!(loss_bits(&a.losses), loss_bits(&b.losses));
    assert_eq!(bits(&a.final_params), bits(&b.final_params));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn resume_with_no_checkpoint_on_disk_starts_fresh() {
    let lib = library();
    let straight = run_data_parallel(lib.clone(), dp_state(2).with_async(false)).unwrap();

    let dir = scratch("dp_fresh"); // never created: nothing to resume from
    let spec = dp_state(2).with_async(false).with_checkpoint(&dir, policy(5, 2)).with_resume();
    let fresh = run_data_parallel(lib.clone(), spec).unwrap();

    assert_eq!(fresh.resumed_from, None);
    assert_eq!(loss_bits(&fresh.losses), loss_bits(&straight.losses));
    assert_eq!(bits(&fresh.final_params), bits(&straight.final_params));
}

#[test]
fn corrupt_manifest_falls_back_to_older_checkpoint() {
    let lib = library();
    let straight = run_data_parallel(lib.clone(), dp_state(4).with_async(false)).unwrap();

    let dir = scratch("dp_corrupt");
    let writer = dp_state(3).with_async(false).with_checkpoint(&dir, policy(1, 3));
    run_data_parallel(lib.clone(), writer).unwrap();
    // torch the newest manifest: discovery must skip step 3 and use step 2
    let manifest = ckdisc::step_dir(&dir, 3).join("world.ck2");
    assert!(manifest.exists());
    std::fs::write(&manifest, b"ADAMACK2 but truncated into garbage").unwrap();

    let spec = dp_state(4).with_async(false).with_checkpoint(&dir, policy(4, 3)).with_resume();
    let resumed = run_data_parallel(lib.clone(), spec).unwrap();
    assert_eq!(resumed.resumed_from, Some(2));
    assert_eq!(loss_bits(&resumed.losses), loss_bits(&straight.losses));
    assert_eq!(bits(&resumed.final_params), bits(&straight.final_params));
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------------
// configuration gates
// ---------------------------------------------------------------------------

#[test]
fn crash_knobs_are_gated_per_engine() {
    let lib = library();
    // the lockstep serial simulator cannot host the barrier protocol
    let dir = scratch("serial_gate");
    let spec = dp_state(1).with_engine(CollectiveEngine::Serial);
    let err = run_data_parallel(lib.clone(), spec.with_checkpoint(&dir, policy(1, 2)));
    let msg = format!("{:?}", err.unwrap_err());
    assert!(msg.contains("serial engine"), "{msg}");

    let spec = z1(OptimizerKind::AdamA, 2, 1).with_engine(CollectiveEngine::Serial);
    let err = run_zero1(lib.clone(), spec.with_fault(FaultPlan { rank: 0, step: 1, op: 0 }));
    let msg = format!("{:?}", err.unwrap_err());
    assert!(msg.contains("serial engine"), "{msg}");

    // fault injection is a fabric feature; the channel ring has no seam
    let spec = dp_state(1).with_engine(CollectiveEngine::Channel);
    let plan = FaultPlan { rank: 0, step: 1, op: 0 };
    let err = run_data_parallel(lib.clone(), spec.with_fault(plan));
    let msg = format!("{:?}", err.unwrap_err());
    assert!(msg.contains("fabric engine"), "{msg}");

    // a plan naming a rank outside the world is a config error up front
    let spec = dp_state(1).with_async(false);
    let plan = FaultPlan { rank: 5, step: 1, op: 0 };
    let err = run_data_parallel(lib.clone(), spec.with_fault(plan));
    let msg = format!("{:?}", err.unwrap_err());
    assert!(msg.contains("rank 5"), "{msg}");

    // resume without a checkpoint directory is an error, not a fresh start
    let err = run_data_parallel(lib, dp_state(1).with_async(false).with_resume());
    let msg = format!("{:?}", err.unwrap_err());
    assert!(msg.contains("checkpoint directory"), "{msg}");
}

// ---------------------------------------------------------------------------
// fault injection: deterministic rank death + supervised recovery.
// `fault_*` tests keep every run either explicitly planned or checkpointed
// so the CI crash-recovery leg (ambient `ADAMA_FAULT=1:2`) passes them too.
// ---------------------------------------------------------------------------

#[test]
fn fault_dp_async_kill_recovers_bit_exact() {
    // THE headline drill: rank 1 dies inside step 3 under async issue;
    // the supervisor reloads the newest world checkpoint (step 2),
    // disarms the fault, and re-runs to completion. Losses, final
    // params and the comm ledger must equal a never-killed twin's bits.
    let lib = library();
    let sdir = scratch("fault_dp_straight");
    let kdir = scratch("fault_dp_killed");
    let mk = |dir: &PathBuf| dp_state(5).with_async(true).with_checkpoint(dir, policy(1, 2));
    let straight = run_data_parallel(lib.clone(), mk(&sdir)).unwrap();

    let plan = FaultPlan { rank: 1, step: 3, op: 1 };
    let killed = run_data_parallel(lib.clone(), mk(&kdir).with_fault(plan)).unwrap();

    assert_eq!(killed.resumed_from, Some(2), "recovered from the step-2 checkpoint");
    assert_eq!(loss_bits(&killed.losses), loss_bits(&straight.losses));
    assert_eq!(bits(&killed.final_params), bits(&straight.final_params));
    assert_eq!(killed.comm_bytes, straight.comm_bytes);
    assert_eq!(killed.comm_ops, straight.comm_ops);
    std::fs::remove_dir_all(&sdir).ok();
    std::fs::remove_dir_all(&kdir).ok();
}

#[test]
fn fault_zero1_async_kill_recovers_bit_exact() {
    let lib = library();
    let sdir = scratch("fault_z1_straight");
    let kdir = scratch("fault_z1_killed");
    let mk = |dir: &PathBuf| {
        z1(OptimizerKind::AdamA, 2, 4).with_async(true).with_checkpoint(dir, policy(1, 2))
    };
    let straight = run_zero1(lib.clone(), mk(&sdir)).unwrap();

    let plan = FaultPlan { rank: 1, step: 3, op: 1 };
    let killed = run_zero1(lib.clone(), mk(&kdir).with_fault(plan)).unwrap();

    assert_eq!(killed.resumed_from, Some(2));
    assert_eq!(loss_bits(&killed.losses), loss_bits(&straight.losses));
    assert_eq!(bits(&killed.final_params), bits(&straight.final_params));
    assert_eq!(killed.comm_bytes, straight.comm_bytes);
    assert_eq!(killed.comm_ops, straight.comm_ops);
    std::fs::remove_dir_all(&sdir).ok();
    std::fs::remove_dir_all(&kdir).ok();
}

#[test]
fn fault_without_checkpoint_surfaces_peer_death() {
    // no checkpoints configured: the supervisor cannot recover, and the
    // typed PeerDeath names the dead rank and step for the caller
    let lib = library();
    let plan = FaultPlan { rank: 1, step: 2, op: 0 };
    let err = run_data_parallel(lib, dp_state(3).with_async(false).with_fault(plan)).unwrap_err();
    let death = err
        .chain()
        .find_map(|c| c.downcast_ref::<PeerDeath>())
        .expect("PeerDeath in the chain");
    assert_eq!(death.rank, 1);
    assert_eq!(death.step, 2);
    assert!(format!("{err:#}").contains("rank 1 died"), "{err:#}");
}

#[test]
fn fault_env_knob_drives_injection() {
    // With `ADAMA_FAULT` exported (the CI crash-recovery leg sets `1:2`)
    // the spec-less path must pick the plan up from the env; when unset,
    // an equivalent explicit plan stands in — either way, without a
    // checkpoint directory the death surfaces as an error.
    let lib = library();
    let mut spec = dp_state(3).with_async(false);
    if FaultPlan::from_env().expect("ADAMA_FAULT must parse").is_none() {
        spec = spec.with_fault(FaultPlan { rank: 1, step: 2, op: 0 });
    }
    let err = run_data_parallel(lib, spec).unwrap_err();
    assert!(err.chain().any(|c| c.downcast_ref::<PeerDeath>().is_some()), "{err:?}");
}
