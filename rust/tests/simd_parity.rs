//! SIMD parity suite: every vector kernel, at every dispatch level the
//! host CPU supports, must be **0-ULP identical** to the scalar
//! reference — at the slice level (against the `host_math` oracles,
//! including remainder lengths that don't divide the lane width) and at
//! the program level (every host program, scalar/SSE2/AVX2/NEON ×
//! packed/naive GEMM engine × 1/4 pool threads, bit-compared against
//! the scalar serial baseline).
//!
//! This is the gate of the `runtime::simd` bit-exactness contract: if a
//! lane kernel reassociates, contracts into FMA, or mishandles a tail,
//! this suite fails before the determinism/backend-parity suites do.

use adama::optim::host_math;
use adama::runtime::simd::{self, Level};
use adama::runtime::{ArtifactEntry, GemmMode, Library, Manifest, MemoryPlan, Value};
use adama::tensor::Rng;

const B1: f32 = 0.9;
const B2: f32 = 0.999;
const EPS: f32 = 1e-8;

fn randvec(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| scale * rng.normal()).collect()
}

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

/// Random lengths spanning sub-lane, lane-multiple and remainder cases,
/// plus pinned awkward edges.
fn sweep_lengths(rng: &mut Rng) -> Vec<usize> {
    let mut lens = vec![0, 1, 2, 3, 4, 5, 7, 8, 9, 15, 16, 17, 31, 32, 33, 1023, 1024, 1025];
    for _ in 0..12 {
        lens.push(1 + rng.below(5000));
    }
    lens
}

/// Slice-level sweep: each dispatched kernel against its `host_math`
/// scalar oracle, all supported levels, remainder lengths included.
#[test]
fn every_simd_kernel_matches_host_math_at_0_ulp() {
    let mut rng = Rng::new(71);
    let levels = Level::all_supported();
    assert!(!levels.is_empty() && levels[0] == Level::Scalar);
    for (case, n) in sweep_lengths(&mut rng).into_iter().enumerate() {
        let m0 = randvec(&mut rng, n, 0.8);
        let v0: Vec<f32> = randvec(&mut rng, n, 0.5).iter().map(|x| x.abs()).collect();
        let p0 = randvec(&mut rng, n, 1.2);
        let g = randvec(&mut rng, n, 2.0);
        for &level in &levels {
            // adama_acc
            let (mut m, mut v) = (m0.clone(), v0.clone());
            simd::adama_acc(level, &mut m, &mut v, &g, 0.25, B1, B2);
            let (mut mw, mut vw) = (m0.clone(), v0.clone());
            host_math::adama_acc(&mut mw, &mut vw, &g, 0.25, B1, B2);
            assert_eq!(bits(&m), bits(&mw), "adama_acc m {} case {case} n={n}", level.name());
            assert_eq!(bits(&v), bits(&vw), "adama_acc v {} case {case} n={n}", level.name());

            // adama_decay_acc
            let (mut m, mut v) = (m0.clone(), v0.clone());
            simd::adama_decay_acc(level, &mut m, &mut v, &g, 0.5, B1, B2, B1, B2);
            let (mut mw, mut vw) = (m0.clone(), v0.clone());
            host_math::adama_decay_acc(&mut mw, &mut vw, &g, 0.5, B1, B2, B1, B2);
            assert_eq!(bits(&m), bits(&mw), "adama_decay_acc m {} n={n}", level.name());
            assert_eq!(bits(&v), bits(&vw), "adama_decay_acc v {} n={n}", level.name());

            // scale
            let mut x = m0.clone();
            simd::scale(level, &mut x, 0.731);
            let mut xw = m0.clone();
            host_math::scale(&mut xw, 0.731);
            assert_eq!(bits(&x), bits(&xw), "scale {} n={n}", level.name());

            // adam_update (v0 is non-negative, as in training)
            let mut p = p0.clone();
            simd::adam_update(level, &mut p, &m0, &v0, 1e-3, 0.1, 0.001, EPS);
            let mut pw = p0.clone();
            host_math::adam_update(&mut pw, &m0, &v0, 1e-3, 0.1, 0.001, EPS);
            assert_eq!(bits(&p), bits(&pw), "adam_update {} n={n}", level.name());

            // adam_full
            let (mut p, mut m, mut v) = (p0.clone(), m0.clone(), v0.clone());
            simd::adam_full(level, &mut p, &mut m, &mut v, &g, 1e-3, 0.1, 0.001, B1, B2, EPS);
            let (mut pw, mut mw, mut vw) = (p0.clone(), m0.clone(), v0.clone());
            host_math::adam_full(&mut pw, &mut mw, &mut vw, &g, 1e-3, 0.1, 0.001, B1, B2, EPS);
            assert_eq!(bits(&p), bits(&pw), "adam_full p {} n={n}", level.name());
            assert_eq!(bits(&m), bits(&mw), "adam_full m {} n={n}", level.name());
            assert_eq!(bits(&v), bits(&vw), "adam_full v {} n={n}", level.name());

            // adamw_update
            let mut p = p0.clone();
            simd::adamw_update(level, &mut p, &m0, &v0, 1e-3, 0.1, 0.001, 0.01, EPS);
            let mut pw = p0.clone();
            host_math::adamw_update(&mut pw, &m0, &v0, 1e-3, 0.1, 0.001, 0.01, EPS);
            assert_eq!(bits(&p), bits(&pw), "adamw_update {} n={n}", level.name());

            // grad_acc
            let mut acc = p0.clone();
            simd::grad_acc(level, &mut acc, &g, 0.25);
            let mut accw = p0.clone();
            host_math::grad_acc(&mut accw, &g, 0.25);
            assert_eq!(bits(&acc), bits(&accw), "grad_acc {} n={n}", level.name());

            // sgdm family
            let mut u = m0.clone();
            simd::sgdm_decay_acc(level, &mut u, &g, 0.5, 0.9);
            simd::sgdm_acc(level, &mut u, &g, 0.5);
            let mut p = p0.clone();
            simd::sgdm_update(level, &mut p, &u, 1e-2, 0.01);
            let mut uw = m0.clone();
            host_math::sgdm_decay_acc(&mut uw, &g, 0.5, 0.9);
            host_math::sgdm_acc(&mut uw, &g, 0.5);
            let mut pw = p0.clone();
            host_math::sgdm_update(&mut pw, &uw, 1e-2, 0.01);
            assert_eq!(bits(&u), bits(&uw), "sgdm acc {} n={n}", level.name());
            assert_eq!(bits(&p), bits(&pw), "sgdm_update {} n={n}", level.name());

            // optimizer-zoo kernels (ADAMA_OPT): fac_update on a row
            // with a non-trivial row factor (v0 is a non-negative
            // column moment, as in training)
            let mut p = p0.clone();
            simd::fac_update(level, &mut p, &g, &v0, 1e-2, 1.25, EPS);
            let mut pw = p0.clone();
            host_math::fac_update(&mut pw, &g, &v0, 1e-2, 1.25, EPS);
            assert_eq!(bits(&p), bits(&pw), "fac_update {} n={n}", level.name());

            // mini_update with a block-shared scale
            let mut p = p0.clone();
            simd::mini_update(level, &mut p, &m0, 3e-3, 0.1);
            let mut pw = p0.clone();
            host_math::mini_update(&mut pw, &m0, 3e-3, 0.1);
            assert_eq!(bits(&p), bits(&pw), "mini_update {} n={n}", level.name());
        }
    }
}

/// Stable per-program input seed (FNV-1a over the name).
fn name_seed(name: &str) -> u64 {
    name.bytes().fold(0xcbf29ce484222325u64, |h, b| (h ^ b as u64).wrapping_mul(0x100000001b3))
}

/// Inputs straight from the manifest entry's tensor specs, with kernel
/// chunk buffers shrunk to an awkward length (5003: above the pool's
/// serial cutoff, not a multiple of any lane width, splits into
/// non-lane-multiple spans at 4 threads). The host kernels are
/// shape-polymorphic, so the chunk size in the name is not binding.
fn gen_inputs(
    entry: &ArtifactEntry,
    i32_cap: usize,
    seed: u64,
    shrink: Option<usize>,
) -> Vec<Value> {
    let mut rng = Rng::new(seed);
    entry
        .inputs
        .iter()
        .map(|spec| {
            if spec.dtype == "s32" {
                let data: Vec<i32> =
                    (0..spec.elements()).map(|_| rng.below(i32_cap) as i32).collect();
                Value::i32(data, &spec.shape).unwrap()
            } else if spec.elements() <= 4 {
                let data: Vec<f32> =
                    (0..spec.elements()).map(|_| 0.5 + rng.uniform()).collect();
                Value::f32(data, &spec.shape).unwrap()
            } else if let Some(n) = shrink {
                // chunk kernels are shape-polymorphic: shrink to the
                // remainder length
                let data: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
                Value::f32(data, &[n]).unwrap()
            } else {
                let data: Vec<f32> =
                    (0..spec.elements()).map(|_| rng.normal()).collect();
                Value::f32(data, &spec.shape).unwrap()
            }
        })
        .collect()
}

fn assert_outputs_bit_equal(name: &str, tag: &str, base: &[Value], got: &[Value]) {
    assert_eq!(base.len(), got.len(), "{name}: arity drift at {tag}");
    for (i, (va, vb)) in base.iter().zip(got).enumerate() {
        match (va.as_f32(), vb.as_f32()) {
            (Ok(a), Ok(b)) => {
                assert_eq!(a.len(), b.len(), "{name} out[{i}]: len drift at {tag}");
                for (j, (x, y)) in a.iter().zip(b).enumerate() {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "{name} out[{i}][{j}]: {x} != {y} at {tag}"
                    );
                }
            }
            _ => assert_eq!(
                va.as_i32().unwrap(),
                vb.as_i32().unwrap(),
                "{name} out[{i}]: i32 drift at {tag}"
            ),
        }
    }
}

/// Program-level sweep of the chunked optimizer kernels: every dispatch
/// level × 1/4 pool threads, on a remainder-length buffer, bit-compared
/// against the scalar 1-thread baseline.
#[test]
fn optimizer_kernel_programs_bit_identical_across_levels_and_threads() {
    let manifest = Manifest::builtin();
    let chunk = *manifest.chunk_sizes.first().unwrap();
    let n = 5003usize;
    let levels = Level::all_supported();

    let names: Vec<String> = manifest
        .common
        .keys()
        .filter(|k| k.ends_with(&format!("_{chunk}")))
        .map(|k| format!("common/{k}"))
        .collect();
    assert!(names.len() >= 11, "expected the full kernel family, got {names:?}");

    for name in names {
        let entry = manifest.entry(&name).unwrap();
        let inputs = gen_inputs(entry, 1, name_seed(&name), Some(n));
        let mut baseline: Option<Vec<Value>> = None;
        for &level in &levels {
            for threads in [1usize, 4] {
                let lib = Library::host_with_simd(threads, MemoryPlan::remat(), level);
                let prog = lib.get(&name).unwrap();
                let out = prog.run_v(&inputs).unwrap();
                match &baseline {
                    None => baseline = Some(out),
                    Some(base) => {
                        let tag = format!("{} x{threads} threads", level.name());
                        assert_outputs_bit_equal(&name, &tag, base, &out);
                    }
                }
            }
        }
    }
}

/// Program-level sweep of the model programs (transformer blocks, heads,
/// embeddings, MLP): every dispatch level × both GEMM engines × 1/4 pool
/// threads must be bit-identical — this covers the SIMD paths inside
/// matmul, layer norm, attention and softmax end to end, and pins the
/// packed engine's fold-order contract at program granularity.
#[test]
fn model_programs_bit_identical_across_levels_engines_and_threads() {
    let manifest = Manifest::builtin();
    let levels = Level::all_supported();

    let mut names: Vec<(String, usize)> = Vec::new();
    for (cfg, entry) in &manifest.configs {
        for key in entry.artifacts.keys() {
            names.push((format!("{cfg}/{key}"), entry.model.vocab));
        }
    }
    for (cfg, entry) in &manifest.mlp_configs {
        for key in entry.artifacts.keys() {
            names.push((format!("mlp_{cfg}/{key}"), entry.model.classes));
        }
    }
    assert!(names.len() >= 12, "model program set unexpectedly small");

    for (name, cap) in names {
        let entry = manifest.entry(&name).unwrap();
        let inputs = gen_inputs(entry, cap, name_seed(&name), None);
        let mut baseline: Option<Vec<Value>> = None;
        for &level in &levels {
            for gm in GemmMode::all() {
                for threads in [1usize, 4] {
                    let lib =
                        Library::host_with_gemm(threads, MemoryPlan::remat(), level, gm);
                    let prog = lib.get(&name).unwrap();
                    let out = prog.run_v(&inputs).unwrap();
                    match &baseline {
                        None => baseline = Some(out),
                        Some(base) => {
                            let tag =
                                format!("{} {} x{threads} threads", level.name(), gm.name());
                            assert_outputs_bit_equal(&name, &tag, base, &out);
                        }
                    }
                }
            }
        }
    }
}

/// The executor reports its dispatch level and GEMM engine, and both
/// survive a DP-style per-rank fork.
#[test]
fn executor_reports_and_forks_its_simd_level() {
    for &level in &Level::all_supported() {
        for gm in GemmMode::all() {
            let lib = Library::host_with_gemm(2, MemoryPlan::remat(), level, gm);
            let exec = lib.executor();
            assert_eq!(exec.simd_level(), Some(level));
            assert_eq!(exec.gemm_mode(), Some(gm));
            let rank = lib.fork_with_threads(1);
            assert_eq!(rank.executor().simd_level(), Some(level), "fork must keep the level");
            assert_eq!(rank.executor().gemm_mode(), Some(gm), "fork must keep the engine");
        }
    }
    // valid ADAMA_SIMD spellings resolve; invalid ones are clear errors
    assert_eq!(Level::parse(Some("scalar")).unwrap(), Level::Scalar);
    assert_eq!(Level::parse(Some("auto")).unwrap(), simd::detect());
    assert_eq!(Level::parse(Some("")).unwrap(), simd::detect());
    assert!(Level::parse(Some("garbage")).is_err());
    // same for ADAMA_GEMM: strict parse, defaults to packed
    assert_eq!(GemmMode::parse(Some("naive")).unwrap(), GemmMode::Naive);
    assert_eq!(GemmMode::parse(Some("packed")).unwrap(), GemmMode::Packed);
    assert_eq!(GemmMode::parse(None).unwrap(), GemmMode::Packed);
    assert!(GemmMode::parse(Some("garbage")).is_err());
}
