//! Integration tests over the execution runtime — kernel programs, model
//! programs and the end-to-end `Trainer`.
//!
//! Run against whichever backend `Library::open_default` selects: the
//! pure-rust host executor on a clean machine, or PJRT + AOT artifacts
//! when built with the `pjrt` feature and `make artifacts` has run.

use adama::runtime::{lit_f32, lit_i32, scalar_f32, to_vec_f32};
use adama::tensor::Rng;

mod common;
use common::{library, B1, B2};

#[test]
fn adama_acc_kernel_matches_host_math() {
    let lib = library();
    let chunk = 16384usize;
    let exe = lib.get(&format!("common/adama_acc_{chunk}")).unwrap();

    let mut rng = Rng::new(1);
    let m: Vec<f32> = (0..chunk).map(|_| rng.normal()).collect();
    let v: Vec<f32> = (0..chunk).map(|_| rng.normal().abs()).collect();
    let g: Vec<f32> = (0..chunk).map(|_| rng.normal()).collect();
    let gscale = 0.25f32;

    let out = exe
        .run_v(&[
            lit_f32(&m, &[chunk]).unwrap(),
            lit_f32(&v, &[chunk]).unwrap(),
            lit_f32(&g, &[chunk]).unwrap(),
            lit_f32(&[gscale], &[1]).unwrap(),
        ])
        .unwrap();
    assert_eq!(out.len(), 2);
    let m2 = to_vec_f32(&out[0]).unwrap();
    let v2 = to_vec_f32(&out[1]).unwrap();

    for i in 0..chunk {
        let sg = g[i] * gscale;
        let want_m = m[i] + (1.0 - B1) * sg;
        let want_v = v[i] + (1.0 - B2) * sg * sg;
        assert!((m2[i] - want_m).abs() < 1e-6, "m[{i}]: {} vs {want_m}", m2[i]);
        assert!((v2[i] - want_v).abs() < 1e-6, "v[{i}]: {} vs {want_v}", v2[i]);
    }
}

#[test]
fn adam_update_kernel_matches_host_math() {
    let lib = library();
    let chunk = 16384usize;
    let exe = lib.get(&format!("common/adam_update_{chunk}")).unwrap();

    let mut rng = Rng::new(2);
    let p: Vec<f32> = (0..chunk).map(|_| rng.normal()).collect();
    let m: Vec<f32> = (0..chunk).map(|_| rng.normal()).collect();
    let v: Vec<f32> = (0..chunk).map(|_| rng.normal().abs()).collect();
    let (lr, bc1, bc2) = (1e-3f32, 0.1f32, 0.001f32);

    let out = exe
        .run_v(&[
            lit_f32(&p, &[chunk]).unwrap(),
            lit_f32(&m, &[chunk]).unwrap(),
            lit_f32(&v, &[chunk]).unwrap(),
            lit_f32(&[lr, bc1, bc2], &[3]).unwrap(),
        ])
        .unwrap();
    let p2 = to_vec_f32(&out[0]).unwrap();
    for i in 0..chunk {
        let want = p[i] - lr * (m[i] / bc1) / ((v[i] / bc2).sqrt() + 1e-8);
        assert!((p2[i] - want).abs() < 1e-5, "p[{i}]: {} vs {want}", p2[i]);
    }
}

#[test]
fn tiny_model_forward_shapes_and_loss() {
    let lib = library();
    let cfg = lib.manifest().model_config("tiny").unwrap().clone();
    let (b, s, h, v) = (cfg.model.microbatch, cfg.model.seq, cfg.model.hidden, cfg.model.vocab);

    let embed = lib.get("tiny/embed_fwd").unwrap();
    let head = lib.get("tiny/head_loss").unwrap();

    let mut rng = Rng::new(3);
    let tokens: Vec<i32> = (0..b * s).map(|_| rng.below(v) as i32).collect();
    let e: Vec<f32> = (0..v * h).map(|_| 0.02 * rng.normal()).collect();
    let p: Vec<f32> = (0..s * h).map(|_| 0.02 * rng.normal()).collect();

    let x = embed
        .run_v(&[
            lit_i32(&tokens, &[b, s]).unwrap(),
            lit_f32(&e, &[v, h]).unwrap(),
            lit_f32(&p, &[s, h]).unwrap(),
        ])
        .unwrap();
    assert_eq!(x.len(), 1);
    let xv = to_vec_f32(&x[0]).unwrap();
    assert_eq!(xv.len(), b * s * h);

    let w: Vec<f32> = (0..h * v).map(|_| 0.02 * rng.normal()).collect();
    let labels: Vec<i32> = (0..b * s).map(|_| rng.below(v) as i32).collect();
    let out = head
        .run_v(&[
            lit_f32(&xv, &[b, s, h]).unwrap(),
            lit_f32(&w, &[h, v]).unwrap(),
            lit_i32(&labels, &[b, s]).unwrap(),
        ])
        .unwrap();
    // (loss, dx, dW)
    assert_eq!(out.len(), 3);
    let loss = scalar_f32(&out[0]).unwrap();
    // near-uniform logits => loss ~ ln(vocab)
    let expect = (v as f32).ln();
    assert!((loss - expect).abs() < 0.5, "loss {loss} vs ln(V) {expect}");
    assert_eq!(out[1].len(), b * s * h);
    assert_eq!(out[2].len(), h * v);
}

#[test]
fn executable_cache_reuses_compilations() {
    let lib = library();
    let _a = lib.get("common/grad_acc_16384").unwrap();
    let mid = lib.compiled_count();
    let _b = lib.get("common/grad_acc_16384").unwrap();
    assert_eq!(lib.compiled_count(), mid);
}

// ---------------------------------------------------------------------------
// Trainer end-to-end (tiny config)
// ---------------------------------------------------------------------------

use adama::config::{OptimBackend, OptimizerKind, TrainConfig};
use adama::data::MarkovCorpus;
use adama::{Category, Trainer};

fn tiny_cfg(opt: OptimizerKind, backend: OptimBackend, n: usize) -> TrainConfig {
    TrainConfig {
        model: "tiny".into(),
        optimizer: opt,
        backend,
        accum_steps: n,
        chunk: 16384,
        steps: 8,
        ..TrainConfig::default()
    }
}

#[test]
fn trainer_loss_decreases_adama_kernel() {
    let lib = library();
    let cfg = tiny_cfg(OptimizerKind::AdamA, OptimBackend::Kernel, 2);
    let mut t = Trainer::new(lib, cfg).unwrap();
    let h = t.spec().hyper.clone();
    let mut corpus = MarkovCorpus::new(h.vocab, 7, 100);
    let mut first = 0.0;
    let mut last = 0.0;
    for step in 0..12 {
        let mbs = corpus.minibatch(2, h.microbatch, h.seq);
        let stats = t.train_step(&mbs).unwrap();
        if step == 0 {
            first = stats.loss;
        }
        last = stats.loss;
    }
    assert!(first > 4.0, "initial loss {first} ~ ln(256)=5.5");
    assert!(last < first - 0.5, "loss must drop: {first} -> {last}");
}

#[test]
fn adama_vs_ga_same_m_different_v() {
    // m_t identical for any N; training trajectories stay close.
    let lib = library();
    let mk = |o| {
        Trainer::new(lib.clone(), tiny_cfg(o, OptimBackend::Host, 4)).unwrap()
    };
    let mut ta = mk(OptimizerKind::AdamA);
    let mut tg = mk(OptimizerKind::AdamGA);
    let h = ta.spec().hyper.clone();
    // identical data streams
    let mut ca = MarkovCorpus::new(h.vocab, 7, 55);
    let mut cg = MarkovCorpus::new(h.vocab, 7, 55);
    for _ in 0..3 {
        let a = ca.minibatch(4, h.microbatch, h.seq);
        let g = cg.minibatch(4, h.microbatch, h.seq);
        ta.train_step(&a).unwrap();
        tg.train_step(&g).unwrap();
    }
    // params close but not identical (v differs by sum-of-squares)
    let mut max_diff = 0.0f32;
    let mut any_diff = false;
    for (pa, pg) in ta.params().iter().zip(tg.params()) {
        for (a, b) in pa.flat.iter().zip(&pg.flat) {
            max_diff = max_diff.max((a - b).abs());
            if (a - b).abs() > 1e-9 {
                any_diff = true;
            }
        }
    }
    assert!(any_diff, "AdamA must differ from Adam pointwise when N>1");
    assert!(max_diff < 0.05, "but trajectories stay close; max diff {max_diff}");
}

#[test]
fn memory_invariants_adama_vs_ga() {
    // DESIGN.md §5.4: GA's gradient peak carries the full model; AdamA's
    // only the largest layer (transient).
    let lib = library();
    let run = |o| {
        let mut t = Trainer::new(lib.clone(), tiny_cfg(o, OptimBackend::Host, 2)).unwrap();
        let h = t.spec().hyper.clone();
        let mut c = MarkovCorpus::new(h.vocab, 7, 9);
        for _ in 0..2 {
            let mbs = c.minibatch(2, h.microbatch, h.seq);
            t.train_step(&mbs).unwrap();
        }
        let p = t.spec().total_params() * 4;
        let maxl = t.spec().max_layer_params() * 4;
        (t.tracker().peak(Category::Gradients), p, maxl)
    };
    let (ga_peak, p, maxl) = run(OptimizerKind::AdamGA);
    let (aa_peak, _, _) = run(OptimizerKind::AdamA);
    assert_eq!(aa_peak, maxl, "AdamA grad peak == max layer");
    assert_eq!(ga_peak, p + maxl, "GA grad peak == full model + transient layer");
    assert!(aa_peak < ga_peak);
}

#[test]
fn kernel_and_host_backends_agree() {
    let lib = library();
    let mut tk =
        Trainer::new(lib.clone(), tiny_cfg(OptimizerKind::AdamA, OptimBackend::Kernel, 2)).unwrap();
    let mut th =
        Trainer::new(lib.clone(), tiny_cfg(OptimizerKind::AdamA, OptimBackend::Host, 2)).unwrap();
    let h = tk.spec().hyper.clone();
    let mut c1 = MarkovCorpus::new(h.vocab, 7, 33);
    let mut c2 = MarkovCorpus::new(h.vocab, 7, 33);

    // After ONE step the backends must agree to float tolerance.
    tk.train_step(&c1.minibatch(2, h.microbatch, h.seq)).unwrap();
    th.train_step(&c2.minibatch(2, h.microbatch, h.seq)).unwrap();
    for (pa, pb) in tk.params().iter().zip(th.params()) {
        for (a, b) in pa.flat.iter().zip(&pb.flat) {
            assert!((a - b).abs() < 2e-5, "kernel {a} vs host {b} after 1 step");
        }
    }

    // Over more steps tiny f32 differences amplify through 1/sqrt(v) when
    // v ~ 0, but the drift must stay bounded by ~one LR-sized step.
    for _ in 0..3 {
        tk.train_step(&c1.minibatch(2, h.microbatch, h.seq)).unwrap();
        th.train_step(&c2.minibatch(2, h.microbatch, h.seq)).unwrap();
    }
    let lr = tk.config().lr.base;
    for (pa, pb) in tk.params().iter().zip(th.params()) {
        for (a, b) in pa.flat.iter().zip(&pb.flat) {
            assert!((a - b).abs() < lr, "kernel {a} vs host {b} drift > lr");
        }
    }
}

#[test]
fn eval_accuracy_improves_with_training() {
    let lib = library();
    let cfg = tiny_cfg(OptimizerKind::AdamA, OptimBackend::Kernel, 2);
    let mut t = Trainer::new(lib, cfg).unwrap();
    let h = t.spec().hyper.clone();
    let mut corpus = MarkovCorpus::new(h.vocab, 7, 1);
    let mut heldout = MarkovCorpus::new(h.vocab, 7, 999);
    let eval_set = heldout.minibatch(4, h.microbatch, h.seq);
    let (loss0, acc0) = t.eval(&eval_set).unwrap();
    for _ in 0..15 {
        let mbs = corpus.minibatch(2, h.microbatch, h.seq);
        t.train_step(&mbs).unwrap();
    }
    let (loss1, acc1) = t.eval(&eval_set).unwrap();
    assert!(loss1 < loss0, "eval loss {loss0} -> {loss1}");
    assert!(acc1 >= acc0, "eval acc {acc0} -> {acc1}");
}

#[test]
fn checkpoint_roundtrip_through_trainer() {
    let lib = library();
    let mut t =
        Trainer::new(lib.clone(), tiny_cfg(OptimizerKind::AdamA, OptimBackend::Host, 2)).unwrap();
    let h = t.spec().hyper.clone();
    let mut c = MarkovCorpus::new(h.vocab, 7, 5);
    t.train_step(&c.minibatch(2, h.microbatch, h.seq)).unwrap();
    let dir = std::env::temp_dir().join("adama_it_ck");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("t.ck");
    t.save_checkpoint(&path).unwrap();
    let mut t2 =
        Trainer::new(lib, tiny_cfg(OptimizerKind::AdamA, OptimBackend::Host, 2)).unwrap();
    t2.load_checkpoint(&path).unwrap();
    for (a, b) in t.params().iter().zip(t2.params()) {
        assert_eq!(a.flat, b.flat);
    }
    std::fs::remove_file(path).ok();
}

#[test]
fn rss_stays_flat_over_training() {
    // Regression test for the upstream xla-0.1.6 `execute()` input-buffer
    // leak (see runtime/pjrt.rs); on the host backend it doubles as a
    // buffer-churn leak check. 60 tiny steps must not grow RSS by more
    // than a few MB once warm.
    fn rss_kb() -> usize {
        std::fs::read_to_string("/proc/self/statm")
            .ok()
            .and_then(|s| s.split_whitespace().nth(1).map(|x| x.parse::<usize>().ok()))
            .flatten()
            .map(|pages| pages * 4)
            .unwrap_or(0)
    }
    let lib = library();
    let mut t =
        Trainer::new(lib, tiny_cfg(OptimizerKind::AdamA, OptimBackend::Kernel, 2)).unwrap();
    let h = t.spec().hyper.clone();
    let mut c = MarkovCorpus::new(h.vocab, 7, 1);
    for _ in 0..10 {
        t.train_step(&c.minibatch(2, h.microbatch, h.seq)).unwrap();
    }
    let warm = rss_kb();
    for _ in 0..50 {
        t.train_step(&c.minibatch(2, h.microbatch, h.seq)).unwrap();
    }
    let grown = rss_kb().saturating_sub(warm);
    assert!(grown < 64 * 1024, "RSS grew {grown} KB over 50 steps (leak?)");
}

#[test]
fn sgdma_extension_trains() {
    // §5 extension: momentum-SGD accumulation learns the task through the
    // same layer-wise release protocol.
    let lib = library();
    let mut cfg = tiny_cfg(OptimizerKind::SgdmA, OptimBackend::Kernel, 2);
    cfg.lr = adama::config::LrSchedule::constant(0.05);
    let mut t = Trainer::new(lib, cfg).unwrap();
    let h = t.spec().hyper.clone();
    let mut c = MarkovCorpus::new(h.vocab, 7, 3);
    let mut first = 0.0;
    let mut last = 0.0;
    for step in 0..15 {
        let s = t.train_step(&c.minibatch(2, h.microbatch, h.seq)).unwrap();
        if step == 0 {
            first = s.loss;
        }
        last = s.loss;
    }
    assert!(last < first - 0.2, "SGDM-A loss {first} -> {last}");
    // and it holds only 1·P of optimizer state
    assert_eq!(
        t.tracker().peak(Category::OptimizerStates),
        t.spec().total_params() * 4
    );
}

#[test]
fn adamwa_weight_decay_shrinks_weight_norm() {
    let lib = library();
    let norm_after = |wd: f32| {
        let mut cfg = tiny_cfg(OptimizerKind::AdamA, OptimBackend::Kernel, 2);
        cfg.weight_decay = wd;
        let mut t = Trainer::new(lib.clone(), cfg).unwrap();
        let h = t.spec().hyper.clone();
        let mut c = MarkovCorpus::new(h.vocab, 7, 4);
        for _ in 0..6 {
            t.train_step(&c.minibatch(2, h.microbatch, h.seq)).unwrap();
        }
        t.params()
            .iter()
            .flat_map(|p| &p.flat)
            .map(|x| (x * x) as f64)
            .sum::<f64>()
            .sqrt()
    };
    let plain = norm_after(0.0);
    let decayed = norm_after(0.5);
    // per-step shrink is (1 - lr*wd) = 0.9995; over 6 steps ~0.3% — small
    // but strictly measurable above float noise.
    assert!(decayed < plain - 0.05, "wd must shrink norm: {plain} vs {decayed}");
}
