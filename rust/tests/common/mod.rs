//! Shared test helpers: artifact discovery + hyper constants.

use std::sync::Arc;

use adama::runtime::ArtifactLibrary;

/// Adam hyper-parameters baked into the artifacts (mirrors ref.py).
pub const B1: f32 = 0.9;
pub const B2: f32 = 0.999;
#[allow(dead_code)]
pub const EPS: f32 = 1e-8;

/// Open the artifact library, or skip (return None) when `make artifacts`
/// has not run — keeps `cargo test` usable before the python build.
pub fn artifacts_or_skip() -> Option<Arc<ArtifactLibrary>> {
    let root = ArtifactLibrary::default_root();
    if !root.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts at {} (run `make artifacts`)", root.display());
        return None;
    }
    Some(ArtifactLibrary::open_default().expect("opening artifact library"))
}
