//! Shared test helpers: library discovery + hyper constants.
#![allow(dead_code)] // each test crate uses a different subset

use std::sync::Arc;

use adama::runtime::Library;

/// Adam hyper-parameters baked into the kernels (mirrors ref.py).
pub const B1: f32 = 0.9;
pub const B2: f32 = 0.999;
pub const EPS: f32 = 1e-8;

/// Open the default execution library. With the `pjrt` feature *and* an
/// artifact directory this is the PJRT backend; otherwise the pure-rust
/// host executor with the built-in manifest — so these tests always have
/// a backend and never skip.
pub fn library() -> Arc<Library> {
    Library::open_default().expect("opening execution library")
}
