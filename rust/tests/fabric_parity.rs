//! Fabric parity suite: the serial simulator, the legacy channel ring and
//! the concurrent fabric must produce **bit-for-bit identical** training
//! runs — losses, final parameters and the comm-volume ledger — for every
//! sync strategy, ZeRO flow, rank count and `ADAMA_THREADS`/`ADAMA_SIMD`
//! setting (the CI `distributed` job sweeps `ADAMA_RANKS={1,2,4} ×
//! ADAMA_THREADS={1,4} × ADAMA_ASYNC={0,1}` — the async legs drive these
//! same env-resolved runs through the fabric comm thread).

use std::sync::Arc;

use adama::collective::{
    run_data_parallel, run_zero1, CollectiveEngine, DpReport, DpSpec, SyncStrategy, Topology,
    Zero1Spec,
};
use adama::config::{OptimBackend, OptimizerKind, TrainConfig};
use adama::runtime::Library;

mod common;
use common::library;

const DATA_SEED: u64 = 41;

fn cfg(opt: OptimizerKind, workers: usize, n: usize) -> TrainConfig {
    TrainConfig {
        model: "tiny".into(),
        optimizer: opt,
        backend: OptimBackend::Host,
        accum_steps: n,
        chunk: 16384,
        workers,
        ..TrainConfig::default()
    }
}

/// Rank counts to sweep: `ADAMA_RANKS` (an integer, or a comma list — the
/// CI distributed matrix sets one value per leg); default `1,2,4`.
fn worlds() -> Vec<usize> {
    match std::env::var("ADAMA_RANKS") {
        Ok(s) if !s.trim().is_empty() => s
            .split(',')
            .map(|t| {
                t.trim()
                    .parse::<usize>()
                    .expect("ADAMA_RANKS: expected comma-separated positive integers")
            })
            .collect(),
        _ => vec![1, 2, 4],
    }
}

fn param_bits(params: &[Vec<f32>]) -> Vec<Vec<u32>> {
    params.iter().map(|l| l.iter().map(|x| x.to_bits()).collect()).collect()
}

fn loss_bits(losses: &[f32]) -> Vec<u32> {
    losses.iter().map(|x| x.to_bits()).collect()
}

fn dp(
    lib: &Arc<Library>,
    m: usize,
    sync: SyncStrategy,
    opt: OptimizerKind,
    engine: CollectiveEngine,
) -> DpReport {
    // pin ring so the 3-engine comparison stays valid even under an
    // ambient ADAMA_FABRIC=tree (the channel engine is ring-only; tree
    // has its own serial-vs-fabric oracle test below)
    run_data_parallel(
        lib.clone(),
        DpSpec::new(cfg(opt, m, 2), sync, 2, DATA_SEED)
            .with_engine(engine)
            .with_topology(Topology::Ring),
    )
    .unwrap_or_else(|e| panic!("{} M={m} {:?}: {e:?}", engine.name(), sync))
}

#[test]
fn dp_concurrent_engines_match_serial_simulator_bit_for_bit() {
    let lib = library();
    for m in worlds() {
        for (sync, opt) in [
            (SyncStrategy::OptimizerStates, OptimizerKind::AdamA),
            (SyncStrategy::Gradients, OptimizerKind::AdamGA),
            (SyncStrategy::GradPerMicrobatch, OptimizerKind::AdamA),
        ] {
            let oracle = dp(&lib, m, sync, opt, CollectiveEngine::Serial);
            for engine in [CollectiveEngine::Channel, CollectiveEngine::Fabric] {
                let got = dp(&lib, m, sync, opt, engine);
                let tag = format!("{} M={m} {:?}", engine.name(), sync);
                assert_eq!(
                    loss_bits(&got.losses),
                    loss_bits(&oracle.losses),
                    "{tag}: losses diverged from serial"
                );
                assert_eq!(
                    param_bits(&got.final_params),
                    param_bits(&oracle.final_params),
                    "{tag}: parameters diverged from serial"
                );
                assert_eq!(got.comm_bytes, oracle.comm_bytes, "{tag}: wire ledger");
                assert_eq!(got.comm_ops, oracle.comm_ops, "{tag}: op ledger");
            }
        }
    }
}

#[test]
fn zero1_concurrent_engines_match_serial_simulator_bit_for_bit() {
    let lib = library();
    for m in worlds().into_iter().filter(|&m| m >= 2) {
        for opt in [OptimizerKind::AdamA, OptimizerKind::AdamGA] {
            let oracle = run_zero1(
                lib.clone(),
                Zero1Spec::new(cfg(opt, m, 2), 2, DATA_SEED)
                    .with_engine(CollectiveEngine::Serial)
                    .with_topology(Topology::Ring),
            )
            .unwrap();
            for engine in [CollectiveEngine::Channel, CollectiveEngine::Fabric] {
                let got = run_zero1(
                    lib.clone(),
                    Zero1Spec::new(cfg(opt, m, 2), 2, DATA_SEED)
                        .with_engine(engine)
                        .with_topology(Topology::Ring),
                )
                .unwrap_or_else(|e| panic!("zero1 {} M={m}: {e:?}", engine.name()));
                let tag = format!("zero1 {} M={m} {:?}", engine.name(), opt);
                assert_eq!(loss_bits(&got.losses), loss_bits(&oracle.losses), "{tag}");
                assert_eq!(
                    param_bits(&got.final_params),
                    param_bits(&oracle.final_params),
                    "{tag}"
                );
                assert_eq!(got.comm_bytes, oracle.comm_bytes, "{tag}: wire ledger");
                assert_eq!(got.comm_ops, oracle.comm_ops, "{tag}: op ledger");
            }
        }
    }
}

#[test]
fn multithreaded_ranks_change_no_bits() {
    // each fabric rank gets an explicit 2-worker intra-op pool (composing
    // with runtime::pool); the serial oracle uses the default even split
    // of ADAMA_THREADS — same bits either way
    let lib = library();
    let oracle = run_data_parallel(
        lib.clone(),
        DpSpec::new(
            cfg(OptimizerKind::AdamA, 2, 2),
            SyncStrategy::OptimizerStates,
            2,
            DATA_SEED,
        )
        .with_engine(CollectiveEngine::Serial),
    )
    .unwrap();
    let wide = run_data_parallel(
        lib,
        DpSpec::new(
            cfg(OptimizerKind::AdamA, 2, 2),
            SyncStrategy::OptimizerStates,
            2,
            DATA_SEED,
        )
        .with_engine(CollectiveEngine::Fabric)
        .with_rank_threads(2),
    )
    .unwrap();
    assert_eq!(param_bits(&wide.final_params), param_bits(&oracle.final_params));
    assert_eq!(loss_bits(&wide.losses), loss_bits(&oracle.losses));
}

#[test]
fn tree_topology_matches_its_own_serial_oracle() {
    // tree and ring bracketings differ; each topology must still be
    // bit-identical between the serial simulator and the fabric
    let lib = library();
    for m in worlds().into_iter().filter(|&m| m >= 2) {
        let mk = |engine| {
            run_data_parallel(
                lib.clone(),
                DpSpec::new(
                    cfg(OptimizerKind::AdamA, m, 2),
                    SyncStrategy::OptimizerStates,
                    2,
                    DATA_SEED,
                )
                .with_engine(engine)
                .with_topology(Topology::Tree),
            )
            .unwrap()
        };
        let oracle = mk(CollectiveEngine::Serial);
        let fab = mk(CollectiveEngine::Fabric);
        assert_eq!(param_bits(&fab.final_params), param_bits(&oracle.final_params), "M={m}");
        assert_eq!(loss_bits(&fab.losses), loss_bits(&oracle.losses), "M={m}");
    }
}

#[test]
fn channel_engine_rejects_tree_topology() {
    // the channel ring implements exactly the ring fold order; a tree
    // request must error, not silently downgrade (which would break the
    // engines-bit-identical invariant)
    let lib = library();
    let err = run_data_parallel(
        lib,
        DpSpec::new(
            cfg(OptimizerKind::AdamA, 2, 2),
            SyncStrategy::OptimizerStates,
            1,
            DATA_SEED,
        )
        .with_engine(CollectiveEngine::Channel)
        .with_topology(Topology::Tree),
    );
    let msg = format!("{:?}", err.unwrap_err());
    assert!(msg.contains("ring"), "{msg}");
}

#[test]
fn async_issue_matches_sync_bit_for_bit() {
    // The tentpole invariant: handing per-layer reductions to the comm
    // thread (any bucket threshold) changes scheduling only — losses,
    // params AND the wire/op ledger stay bit-identical to blocking issue
    // and to the serial oracle, for both topologies and with a
    // multithreaded per-rank pool.
    let lib = library();
    for m in worlds().into_iter().filter(|&m| m >= 2) {
        for topo in [Topology::Ring, Topology::Tree] {
            let z = |engine, async_issue: bool, bucket: usize, threads: usize| {
                run_zero1(
                    lib.clone(),
                    Zero1Spec::new(cfg(OptimizerKind::AdamA, m, 2), 2, DATA_SEED)
                        .with_engine(engine)
                        .with_topology(topo)
                        .with_rank_threads(threads)
                        .with_async(async_issue)
                        .with_bucket_bytes(bucket),
                )
                .unwrap_or_else(|e| panic!("zero1 async M={m} {topo:?}: {e:?}"))
            };
            let sync = z(CollectiveEngine::Fabric, false, 0, 1);
            // bucket sweep: per-layer issue, mid-size coalescing, one
            // giant bucket (collapses to a single post-backward batch)
            for bucket in [0usize, 4 << 10, 1 << 30] {
                let got = z(CollectiveEngine::Fabric, true, bucket, 1);
                let tag = format!("zero1 async M={m} {topo:?} bucket={bucket}");
                assert_eq!(loss_bits(&got.losses), loss_bits(&sync.losses), "{tag}");
                assert_eq!(
                    param_bits(&got.final_params),
                    param_bits(&sync.final_params),
                    "{tag}"
                );
                assert_eq!(got.comm_bytes, sync.comm_bytes, "{tag}: wire ledger");
                assert_eq!(got.comm_ops, sync.comm_ops, "{tag}: op ledger");
            }
            // multithreaded ranks under async issue change no bits either
            let wide = z(CollectiveEngine::Fabric, true, 4 << 10, 2);
            assert_eq!(param_bits(&wide.final_params), param_bits(&sync.final_params));
            assert_eq!(loss_bits(&wide.losses), loss_bits(&sync.losses));
            // the serial engine's blocking shims accept the same spec
            let ser = z(CollectiveEngine::Serial, true, 4 << 10, 1);
            assert_eq!(loss_bits(&ser.losses), loss_bits(&sync.losses));
            assert_eq!(param_bits(&ser.final_params), param_bits(&sync.final_params));
            assert_eq!(ser.comm_bytes, sync.comm_bytes);
            assert_eq!(ser.comm_ops, sync.comm_ops);
        }
    }
    // DP state-sync async twin: m/v all-reduces issued as tickets
    let dp_run = |async_issue: bool| {
        run_data_parallel(
            lib.clone(),
            DpSpec::new(
                cfg(OptimizerKind::AdamA, 2, 2),
                SyncStrategy::OptimizerStates,
                2,
                DATA_SEED,
            )
            .with_engine(CollectiveEngine::Fabric)
            .with_topology(Topology::Ring)
            .with_async(async_issue),
        )
        .unwrap()
    };
    let s = dp_run(false);
    let a = dp_run(true);
    assert_eq!(loss_bits(&a.losses), loss_bits(&s.losses), "dp async losses");
    assert_eq!(param_bits(&a.final_params), param_bits(&s.final_params), "dp async params");
    assert_eq!(a.comm_bytes, s.comm_bytes, "dp async wire ledger");
    assert_eq!(a.comm_ops, s.comm_ops, "dp async op ledger");
}

#[test]
fn per_rank_memory_is_reported_and_aggregates() {
    let lib = library();
    for m in worlds() {
        let r = dp(
            &lib,
            m,
            SyncStrategy::OptimizerStates,
            OptimizerKind::AdamA,
            CollectiveEngine::Fabric,
        );
        assert_eq!(r.per_rank_memory.len(), m, "one snapshot per rank");
        let world = r.world_memory();
        assert_eq!(world.world(), m);
        let mx = world.max_per_rank().expect("non-empty world");
        assert!(mx.tracker.peak_total > 0);
        assert!(world.total_peak_bytes() >= mx.tracker.peak_total as u64);
        // every rank holds a full replica: identical weight peaks
        for snap in &r.per_rank_memory {
            assert_eq!(snap.tracker.peak_weights, mx.tracker.peak_weights);
        }
    }
}
