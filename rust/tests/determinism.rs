//! Determinism suite: the host executor's thread pool must be a pure
//! performance knob — **bit-for-bit identical** results at any
//! `ADAMA_THREADS` setting.
//!
//! * every builtin host program (optimizer kernels at all chunk sizes,
//!   MLP train/eval, transformer embed/block/head fwd+bwd, both configs)
//!   is run on identical inputs at 1, 2, 3 and 8 pool threads and the
//!   outputs compared by bit pattern;
//! * a full 20-step MLP training run and a 20-step tiny-transformer
//!   training run must reach identical per-step losses and identical
//!   final parameter bit patterns serial vs parallel;
//! * the `ADAMA_THREADS` resolution rules are pinned down.

use std::sync::Arc;

use adama::config::{LrSchedule, OptimBackend, OptimizerKind, TrainConfig};
use adama::coordinator::MlpTrainer;
use adama::data::{BlobData, MarkovCorpus};
use adama::runtime::{ArtifactEntry, Library, Manifest, Value};
use adama::tensor::Rng;
use adama::Trainer;

const THREAD_COUNTS: [usize; 4] = [1, 2, 3, 8];

/// Stable per-program input seed (FNV-1a over the name).
fn name_seed(name: &str) -> u64 {
    name.bytes().fold(0xcbf29ce484222325u64, |h, b| (h ^ b as u64).wrapping_mul(0x100000001b3))
}

/// Generate inputs straight from the manifest entry's tensor specs:
/// s32 tensors get values in `[0, i32_cap)` (tokens/labels), tiny f32
/// tensors (scalar packs like `[lr, bc1, bc2]`) get positive values away
/// from zero, everything else is standard normal.
fn gen_inputs(entry: &ArtifactEntry, i32_cap: usize, seed: u64) -> Vec<Value> {
    let mut rng = Rng::new(seed);
    entry
        .inputs
        .iter()
        .map(|spec| {
            if spec.dtype == "s32" {
                let data: Vec<i32> =
                    (0..spec.elements()).map(|_| rng.below(i32_cap) as i32).collect();
                Value::i32(data, &spec.shape).unwrap()
            } else if spec.elements() <= 4 {
                let data: Vec<f32> =
                    (0..spec.elements()).map(|_| 0.5 + rng.uniform()).collect();
                Value::f32(data, &spec.shape).unwrap()
            } else {
                let data: Vec<f32> = (0..spec.elements()).map(|_| rng.normal()).collect();
                Value::f32(data, &spec.shape).unwrap()
            }
        })
        .collect()
}

fn assert_values_bit_equal(name: &str, threads: usize, base: &[Value], got: &[Value]) {
    assert_eq!(base.len(), got.len(), "{name}: output arity changed at {threads} threads");
    for (i, (va, vb)) in base.iter().zip(got).enumerate() {
        assert_eq!(va.shape(), vb.shape(), "{name} out[{i}]: shape drift at {threads} threads");
        match (va.as_f32(), vb.as_f32()) {
            (Ok(a), Ok(b)) => {
                for (j, (x, y)) in a.iter().zip(b).enumerate() {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "{name} out[{i}][{j}]: {x} != {y} at {threads} threads"
                    );
                }
            }
            _ => {
                assert_eq!(
                    va.as_i32().unwrap(),
                    vb.as_i32().unwrap(),
                    "{name} out[{i}]: i32 drift at {threads} threads"
                );
            }
        }
    }
}

/// Every builtin program, identical inputs, 1/2/3/8 pool threads →
/// identical output bits.
#[test]
fn every_host_program_is_bitwise_identical_across_thread_counts() {
    let manifest = Manifest::builtin();
    let libs: Vec<Arc<Library>> =
        THREAD_COUNTS.iter().map(|&t| Library::host_with_threads(t)).collect();

    // (program name, cap for s32 inputs)
    let mut names: Vec<(String, usize)> = Vec::new();
    for key in manifest.common.keys() {
        names.push((format!("common/{key}"), 1));
    }
    for (cfg, entry) in &manifest.configs {
        for key in entry.artifacts.keys() {
            names.push((format!("{cfg}/{key}"), entry.model.vocab));
        }
    }
    for (cfg, entry) in &manifest.mlp_configs {
        for key in entry.artifacts.keys() {
            names.push((format!("mlp_{cfg}/{key}"), entry.model.classes));
        }
    }
    assert!(names.len() > 40, "builtin manifest unexpectedly small");

    for (name, cap) in names {
        let entry = manifest.entry(&name).unwrap_or_else(|| panic!("no entry {name}"));
        let inputs = gen_inputs(entry, cap, name_seed(&name));
        let mut baseline: Option<Vec<Value>> = None;
        for (lib, &threads) in libs.iter().zip(THREAD_COUNTS.iter()) {
            let prog = lib.get(&name).unwrap_or_else(|e| panic!("loading {name}: {e:?}"));
            let out = prog
                .run_v(&inputs)
                .unwrap_or_else(|e| panic!("running {name} at {threads} threads: {e:?}"));
            match &baseline {
                None => baseline = Some(out),
                Some(base) => assert_values_bit_equal(&name, threads, base, &out),
            }
        }
    }
}

/// 20 MLP training steps (AdamA, kernel backend): per-step loss bits and
/// final parameter bits are identical at every thread count.
fn mlp_training_run(threads: usize) -> (Vec<u32>, Vec<Vec<u32>>) {
    let lib = Library::host_with_threads(threads);
    let cfg = TrainConfig {
        model: "tiny".into(),
        optimizer: OptimizerKind::AdamA,
        backend: OptimBackend::Kernel,
        accum_steps: 4,
        lr: LrSchedule::constant(5e-2),
        ..TrainConfig::default()
    };
    let mut trainer = MlpTrainer::new(lib, cfg).unwrap();
    let h = trainer.hyper.clone();
    let mut data = BlobData::new(h.features, h.classes, 5, 6);
    let mut losses = Vec::with_capacity(20);
    for _ in 0..20 {
        let mbs: Vec<_> = (0..4).map(|_| data.batch(h.microbatch)).collect();
        losses.push(trainer.train_step(&mbs).unwrap().to_bits());
    }
    let params = trainer
        .params()
        .iter()
        .map(|p| p.flat.iter().map(|x| x.to_bits()).collect())
        .collect();
    (losses, params)
}

#[test]
fn mlp_training_is_bitwise_identical_serial_vs_parallel() {
    let (base_losses, base_params) = mlp_training_run(1);
    assert!(base_losses.len() == 20);
    for threads in [2usize, 3, 8] {
        let (losses, params) = mlp_training_run(threads);
        assert_eq!(base_losses, losses, "MLP loss bits drifted at {threads} threads");
        assert_eq!(base_params, params, "MLP final params drifted at {threads} threads");
    }
}

/// 20 tiny-transformer training steps (AdamA release-per-layer, kernel
/// backend): identical loss trajectory and final parameter bits.
fn lm_training_run(threads: usize) -> (Vec<u32>, Vec<Vec<u32>>) {
    let lib = Library::host_with_threads(threads);
    let cfg = TrainConfig {
        model: "tiny".into(),
        optimizer: OptimizerKind::AdamA,
        backend: OptimBackend::Kernel,
        accum_steps: 2,
        chunk: 16384,
        seed: 42,
        ..TrainConfig::default()
    };
    let mut trainer = Trainer::new(lib, cfg).unwrap();
    let h = trainer.spec().hyper.clone();
    let mut corpus = MarkovCorpus::new(h.vocab, 7, 1);
    let mut losses = Vec::with_capacity(20);
    for _ in 0..20 {
        let mbs = corpus.minibatch(2, h.microbatch, h.seq);
        let stats = trainer.train_step(&mbs).unwrap();
        losses.push(stats.loss.to_bits());
    }
    let params = trainer
        .params()
        .iter()
        .map(|p| p.flat.iter().map(|x| x.to_bits()).collect())
        .collect();
    (losses, params)
}

#[test]
fn transformer_training_is_bitwise_identical_serial_vs_parallel() {
    let (base_losses, base_params) = lm_training_run(1);
    assert!(base_losses.len() == 20);
    for threads in [2usize, 3, 8] {
        let (losses, params) = lm_training_run(threads);
        assert_eq!(base_losses, losses, "LM loss bits drifted at {threads} threads");
        assert_eq!(base_params, params, "LM final params drifted at {threads} threads");
    }
}

/// 12 tiny-transformer steps under an `ADAMA_OPT` zoo rule selected at
/// the executor seam: the run must repeat bit-for-bit and be invariant
/// to the pool thread count, exactly like the flagship AdamA path.
fn zoo_training_run(algo: adama::runtime::OptAlgo, threads: usize) -> (Vec<u32>, Vec<Vec<u32>>) {
    let lib = Library::host_with_threads(threads).fork_with_opt(Some(algo));
    let cfg = TrainConfig {
        model: "tiny".into(),
        backend: OptimBackend::Kernel,
        accum_steps: 2,
        chunk: 16384,
        seed: 42,
        ..TrainConfig::default()
    };
    let mut trainer = Trainer::new(lib, cfg).unwrap();
    let h = trainer.spec().hyper.clone();
    let mut corpus = MarkovCorpus::new(h.vocab, 7, 1);
    let mut losses = Vec::with_capacity(12);
    for _ in 0..12 {
        let mbs = corpus.minibatch(2, h.microbatch, h.seq);
        losses.push(trainer.train_step(&mbs).unwrap().loss.to_bits());
    }
    let params = trainer
        .params()
        .iter()
        .map(|p| p.flat.iter().map(|x| x.to_bits()).collect())
        .collect();
    (losses, params)
}

#[test]
fn zoo_rules_are_bitwise_identical_across_reruns_and_thread_counts() {
    for algo in adama::runtime::OptAlgo::ALL {
        let (base_losses, base_params) = zoo_training_run(algo, 1);
        assert!(base_losses.len() == 12);
        let (rerun_losses, rerun_params) = zoo_training_run(algo, 1);
        assert_eq!(base_losses, rerun_losses, "{}: rerun loss bits drifted", algo.name());
        assert_eq!(base_params, rerun_params, "{}: rerun params drifted", algo.name());
        for threads in [3usize, 8] {
            let (losses, params) = zoo_training_run(algo, threads);
            assert_eq!(
                base_losses,
                losses,
                "{}: loss bits drifted at {threads} threads",
                algo.name()
            );
            assert_eq!(
                base_params,
                params,
                "{}: final params drifted at {threads} threads",
                algo.name()
            );
        }
    }
}

/// `ADAMA_THREADS` resolution: positive integers pin the pool,
/// unset/`auto` means available parallelism, anything else is a clear
/// error; the executor reads it at construction time.
#[test]
fn adama_threads_env_knob() {
    use adama::runtime::pool::resolve_threads;
    use adama::runtime::Executor;

    assert_eq!(resolve_threads(Some("3")).unwrap(), 3);
    assert_eq!(resolve_threads(Some(" 8 ")).unwrap(), 8);
    let hw = resolve_threads(None).unwrap();
    assert!(hw >= 1);
    assert_eq!(resolve_threads(Some("auto")).unwrap(), hw);
    assert!(resolve_threads(Some("0")).is_err());
    assert!(resolve_threads(Some("not-a-number")).is_err());

    // executor construction honours the env var (no other test in this
    // binary reads it — they pin thread counts explicitly); restore the
    // prior value so a CI-wide ADAMA_THREADS setting survives this test
    let prior = std::env::var("ADAMA_THREADS").ok();
    std::env::set_var("ADAMA_THREADS", "3");
    let exec = adama::runtime::HostExecutor::new();
    match prior {
        Some(v) => std::env::set_var("ADAMA_THREADS", v),
        None => std::env::remove_var("ADAMA_THREADS"),
    }
    assert_eq!(exec.threads(), 3);
}
