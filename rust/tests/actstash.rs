//! Stash-vs-remat acceptance suite for the host executor's activation
//! memory manager (`ADAMA_ACT_BUDGET` / `MemoryPlan`).
//!
//! * **Bit parity** — full training runs with stashed and rematerialised
//!   `block_bwd` must produce identical per-step loss bits and final
//!   parameter bits, at 1 and 4 pool threads, for budgets half and
//!   unlimited against the remat baseline.
//! * **Accounting reconciliation** — the executor's measured stash-arena
//!   and workspace peaks must match the analytic
//!   `memmodel::HostBlockDims` predictions at budgets 0, half and
//!   unlimited: the measured-vs-predicted gap is an invariant, not a
//!   report.

use adama::config::{OptimBackend, OptimizerKind, TrainConfig};
use adama::data::MarkovCorpus;
use adama::memmodel::HostBlockDims;
use adama::runtime::{Library, MemoryPlan};
use adama::Trainer;

fn cfg() -> TrainConfig {
    TrainConfig {
        model: "tiny".into(),
        optimizer: OptimizerKind::AdamA,
        backend: OptimBackend::Kernel,
        accum_steps: 2,
        chunk: 16384,
        seed: 42,
        ..TrainConfig::default()
    }
}

/// Byte budget that fits exactly half of the tiny model's blocks.
fn half_budget(lib: &Library) -> MemoryPlan {
    let hyper = lib.manifest().model_config("tiny").unwrap().model.clone();
    let dims = HostBlockDims::from_model(&hyper);
    MemoryPlan::bytes(dims.stash_entry_bytes() * hyper.layers as u64 / 2)
}

/// Train 6 steps; return (per-step loss bits, final parameter bits).
fn train_run(threads: usize, plan: MemoryPlan) -> (Vec<u32>, Vec<Vec<u32>>) {
    let lib = Library::host_with_plan(threads, plan);
    let mut trainer = Trainer::new(lib, cfg()).unwrap();
    let h = trainer.spec().hyper.clone();
    let mut corpus = MarkovCorpus::new(h.vocab, 7, 1);
    let mut losses = Vec::new();
    for _ in 0..6 {
        let mbs = corpus.minibatch(2, h.microbatch, h.seq);
        losses.push(trainer.train_step(&mbs).unwrap().loss.to_bits());
    }
    let params = trainer
        .params()
        .iter()
        .map(|p| p.flat.iter().map(|x| x.to_bits()).collect())
        .collect();
    (losses, params)
}

#[test]
fn stashed_training_is_bit_identical_to_remat_at_1_and_4_threads() {
    for threads in [1usize, 4] {
        let (base_losses, base_params) = train_run(threads, MemoryPlan::remat());
        let half = half_budget(&Library::host());
        for (name, plan) in [("half", half), ("unlimited", MemoryPlan::unlimited())] {
            let (losses, params) = train_run(threads, plan);
            assert_eq!(
                base_losses, losses,
                "loss bits drifted under budget {name} at {threads} threads"
            );
            assert_eq!(
                base_params, params,
                "final params drifted under budget {name} at {threads} threads"
            );
        }
    }
}

#[test]
fn stash_counters_reflect_the_budget() {
    let lib = Library::host_with_plan(1, MemoryPlan::unlimited());
    let mut trainer = Trainer::new(lib.clone(), cfg()).unwrap();
    let h = trainer.spec().hyper.clone();
    let blocks = h.layers as u64;
    let mut corpus = MarkovCorpus::new(h.vocab, 7, 1);
    let steps = 3u64;
    let micro = 2u64;
    for _ in 0..steps {
        trainer.train_step(&corpus.minibatch(micro as usize, h.microbatch, h.seq)).unwrap();
    }
    let mem = lib.executor().memory().unwrap();
    // every block forward stashed, every block backward hit the stash
    assert_eq!(mem.stashed, steps * micro * blocks);
    assert_eq!(mem.stash_hits, steps * micro * blocks);
    assert_eq!(mem.remats, 0, "unlimited budget must never rematerialise");
    assert_eq!(mem.stash_evictions, 0);
    assert_eq!(mem.stash_live_bytes, 0, "all stashes consumed at step end");
}

#[test]
fn measured_peaks_match_memmodel_for_budget_0_half_unlimited() {
    let base = Library::host();
    let hyper = base.manifest().model_config("tiny").unwrap().model.clone();
    let dims = HostBlockDims::from_model(&hyper);
    let blocks = hyper.layers as u64;
    let vocab = hyper.vocab as u64;
    let entry = dims.stash_entry_bytes();

    for (name, plan, want_hits) in [
        ("0", MemoryPlan::remat(), false),
        ("half", MemoryPlan::bytes(entry * blocks / 2), true),
        ("unlimited", MemoryPlan::unlimited(), true),
    ] {
        let lib = Library::host_with_plan(1, plan);
        let mut trainer = Trainer::new(lib.clone(), cfg()).unwrap();
        let h = trainer.spec().hyper.clone();
        let mut corpus = MarkovCorpus::new(h.vocab, 7, 1);
        for _ in 0..2 {
            trainer.train_step(&corpus.minibatch(2, h.microbatch, h.seq)).unwrap();
        }
        let mem = lib.executor().memory().unwrap();

        // stash arena: measured peak == analytic prediction, exactly
        let predicted = dims.predicted_stash_peak_bytes(plan, blocks);
        assert_eq!(
            mem.stash_peak_bytes, predicted,
            "stash peak mismatch under budget {name}"
        );

        // workspace: every transient of the step (block programs AND the
        // metered head logits, including the GEMM engine's packing
        // panels) is modelled exactly; measured peak must equal the
        // step-level prediction under the executor's actual engine
        let gm = lib.executor().gemm_mode().expect("host executor reports its gemm engine");
        let ws_pred = dims.predicted_step_workspace_peak_bytes(plan, blocks, vocab, gm);
        assert_eq!(
            mem.workspace_peak_bytes, ws_pred,
            "workspace peak mismatch under budget {name}"
        );
        assert_eq!(mem.workspace_live_bytes, 0, "workspace must drain between calls");

        if want_hits {
            assert!(mem.stash_hits > 0, "budget {name} must produce stash hits");
        } else {
            assert_eq!(mem.stashed, 0, "budget 0 must never stash");
        }
    }
}

#[test]
fn coordinator_metrics_surface_the_memory_snapshot() {
    let lib = Library::host_with_plan(1, MemoryPlan::unlimited());
    let mut trainer = Trainer::new(lib, cfg()).unwrap();
    let h = trainer.spec().hyper.clone();
    let mut corpus = MarkovCorpus::new(h.vocab, 7, 1);
    trainer.train_step(&corpus.minibatch(2, h.microbatch, h.seq)).unwrap();
    let snap = trainer.metrics().memory().expect("train_step records a memory snapshot");
    let host = snap.host.expect("host executor instruments memory");
    assert!(host.stash_peak_bytes > 0);
    assert!(snap.tracker.peak_activations > 0);
    assert!(snap.activation_peak_bytes() >= host.stash_peak_bytes);
    // the report serialises with both coordinator and executor fields
    let json = trainer.metrics().to_json_full().to_string_compact();
    assert!(json.contains("host_stash_peak") && json.contains("peak_activations"));
}

#[test]
fn eviction_keeps_the_arena_within_a_byte_budget() {
    let base = Library::host();
    let hyper = base.manifest().model_config("tiny").unwrap().model.clone();
    let dims = HostBlockDims::from_model(&hyper);
    // room for exactly one block of the two
    let plan = MemoryPlan::bytes(dims.stash_entry_bytes());
    let lib = Library::host_with_plan(1, plan);
    let mut trainer = Trainer::new(lib.clone(), cfg()).unwrap();
    let h = trainer.spec().hyper.clone();
    let mut corpus = MarkovCorpus::new(h.vocab, 7, 1);
    for _ in 0..2 {
        trainer.train_step(&corpus.minibatch(2, h.microbatch, h.seq)).unwrap();
    }
    let mem = lib.executor().memory().unwrap();
    assert!(mem.stash_peak_bytes <= dims.stash_entry_bytes());
    assert!(mem.stash_evictions > 0, "overflow must evict, not grow");
    assert!(mem.stash_hits > 0, "the newest block still hits");
    assert!(mem.remats > 0, "the evicted block rematerialises");
}
