//! Serving engine suite: KV-cached decode parity against the
//! full-context forward (the headline bit-exactness contract), batching
//! independence, continuous-batching determinism under arbitrary arrival
//! interleavings, KV-budget eviction gating, memmodel reconciliation of
//! measured KV bytes, and ADAMACK1/ADAMACK2 checkpoint round-trips into
//! the server.

use adama::config::{OptimBackend, OptimizerKind, TrainConfig};
use adama::data::MarkovCorpus;
use adama::memmodel::HostBlockDims;
use adama::model::LayerParams;
use adama::runtime::{GemmMode, Library, MemoryPlan, SimdLevel};
use adama::serve::{DecodeEntry, InferenceEngine, Scheduler, SyntheticLoad};
use adama::Trainer;

mod common;
use common::library;

const SEED: u64 = 3;
const PROMPT: [i32; 6] = [7, 3, 99, 14, 200, 42];

fn engine_on(threads: usize, lvl: SimdLevel, gm: GemmMode) -> InferenceEngine {
    let lib = Library::host_with_gemm(threads, MemoryPlan::remat(), lvl, gm);
    InferenceEngine::init_random(lib, "tiny", SEED).unwrap()
}

/// Last-position logits of a single full-context forward over `tokens`.
fn full_context_logits(eng: &InferenceEngine, tokens: &[i32]) -> Vec<f32> {
    let mut cache = eng.new_cache();
    let (logits, _) = eng
        .decode_logits(&mut [DecodeEntry { cache: &mut cache, pending: tokens }])
        .unwrap();
    logits
}

/// Feed `prompt` one token at a time through a growing KV cache, then
/// greedily decode `extra` more tokens. Returns (generated, final logits).
fn incremental_greedy(eng: &InferenceEngine, prompt: &[i32], extra: usize) -> (Vec<i32>, Vec<f32>) {
    let mut cache = eng.new_cache();
    let mut last = (Vec::new(), Vec::new());
    for &t in prompt {
        let (logits, next) = eng
            .decode_logits(&mut [DecodeEntry { cache: &mut cache, pending: &[t] }])
            .unwrap();
        last = (next, logits);
    }
    let mut generated = Vec::new();
    for _ in 0..extra {
        let t = last.0[0];
        generated.push(t);
        let (logits, next) = eng
            .decode_logits(&mut [DecodeEntry { cache: &mut cache, pending: &[t] }])
            .unwrap();
        last = (next, logits);
    }
    (generated, last.1)
}

fn bits(x: &[f32]) -> Vec<u32> {
    x.iter().map(|v| v.to_bits()).collect()
}

// ---------------------------------------------------------------------------
// headline: KV-cached decode ≡ full-context forward, at 0 ULP, everywhere
// ---------------------------------------------------------------------------

/// Token-by-token decode through the KV cache must be bit-identical to
/// recomputing the full context from scratch — at every thread count ×
/// SIMD level × GEMM mode — and all combos must agree with each other.
#[test]
fn decode_parity_across_threads_simd_and_gemm() {
    const EXTRA: usize = 5;
    let mut reference: Option<(Vec<i32>, Vec<u32>)> = None;
    for threads in [1usize, 4] {
        for lvl in SimdLevel::all_supported() {
            for gm in [GemmMode::Packed, GemmMode::Naive] {
                let tag = format!("threads={threads} simd={lvl:?} gemm={gm:?}");
                let eng = engine_on(threads, lvl, gm);

                // incremental greedy chain through the cache...
                let (generated, inc_logits) = incremental_greedy(&eng, &PROMPT, EXTRA);

                // ...must match a from-scratch full-context forward at
                // every intermediate step, not just the last one.
                let mut ctx = PROMPT.to_vec();
                for (k, &tok) in generated.iter().enumerate() {
                    let full = full_context_logits(&eng, &ctx);
                    let argmax = full
                        .iter()
                        .enumerate()
                        .fold(0usize, |b, (j, &v)| if v > full[b] { j } else { b });
                    assert_eq!(argmax as i32, tok, "{tag}: greedy token {k} diverged");
                    ctx.push(tok);
                }
                let full_last = full_context_logits(&eng, &ctx);
                assert_eq!(bits(&full_last), bits(&inc_logits), "{tag}: final logits");

                match &reference {
                    None => reference = Some((generated, bits(&inc_logits))),
                    Some((rt, rb)) => {
                        assert_eq!(rt, &generated, "{tag}: tokens vs reference combo");
                        assert_eq!(rb, &bits(&inc_logits), "{tag}: logits vs reference combo");
                    }
                }
            }
        }
    }
}

/// Rows of a ragged batch are mathematically independent: decoding three
/// sequences together yields the same bits as decoding each alone.
#[test]
fn ragged_batch_rows_are_independent() {
    let eng = engine_on(2, SimdLevel::Scalar, GemmMode::Packed);
    let seqs: [&[i32]; 3] = [&[1, 2, 3, 4, 5, 6, 7], &[9], &[100, 101, 102]];

    let solo: Vec<Vec<f32>> = seqs.iter().map(|s| full_context_logits(&eng, s)).collect();

    let mut caches: Vec<_> = (0..3).map(|_| eng.new_cache()).collect();
    let mut entries: Vec<DecodeEntry<'_>> = caches
        .iter_mut()
        .zip(&seqs)
        .map(|(cache, s)| DecodeEntry { cache, pending: s })
        .collect();
    let (batched, _) = eng.decode_logits(&mut entries).unwrap();

    let v = eng.hyper().vocab;
    for (r, alone) in solo.iter().enumerate() {
        assert_eq!(
            bits(alone),
            bits(&batched[r * v..(r + 1) * v]),
            "row {r} depends on its batch neighbours"
        );
    }
}

// ---------------------------------------------------------------------------
// continuous batching: schedule shape never changes the tokens
// ---------------------------------------------------------------------------

fn scheduled_tokens(
    max_batch: usize,
    arrive_every: usize,
    budget: Option<u64>,
) -> Vec<(Vec<i32>, u32)> {
    let eng = engine_on(2, SimdLevel::Scalar, GemmMode::Packed);
    let load = SyntheticLoad { requests: 4, prompt_len: 5, max_new: 4, arrive_every, seed: 9 };
    let prompts = load.prompts(eng.hyper().vocab);
    let mut s = Scheduler::with_budget(eng, max_batch, budget);
    let (mut submitted, mut tick) = (0usize, 0usize);
    while submitted < prompts.len() || !s.is_idle() {
        while submitted < prompts.len()
            && (arrive_every == 0 || tick >= submitted * arrive_every)
        {
            s.submit(&prompts[submitted], load.max_new).unwrap();
            submitted += 1;
        }
        s.step().unwrap();
        if let Some(cap) = budget {
            assert!(
                s.kv_live_bytes() <= cap,
                "live KV {} exceeds ADAMA_KV_BUDGET {cap}",
                s.kv_live_bytes()
            );
        }
        tick += 1;
    }
    let mut done = s.take_completed();
    assert_eq!(done.len(), prompts.len());
    done.sort_by_key(|c| c.id);
    done.into_iter().map(|c| (c.tokens, c.prefills)).collect()
}

/// Any batch width and any arrival interleaving must produce the same
/// tokens per request — batching is a throughput decision, never a
/// correctness one.
#[test]
fn continuous_batching_is_arrival_invariant() {
    let reference = scheduled_tokens(1, 0, None);
    for (tokens, prefills) in &reference {
        assert_eq!(tokens.len(), 4);
        assert_eq!(*prefills, 1);
    }
    for (max_batch, arrive_every) in [(2, 1), (4, 0), (3, 2), (2, 3)] {
        let got = scheduled_tokens(max_batch, arrive_every, None);
        let toks = |v: &Vec<(Vec<i32>, u32)>| v.iter().map(|(t, _)| t.clone()).collect::<Vec<_>>();
        assert_eq!(
            toks(&reference),
            toks(&got),
            "tokens changed under max_batch={max_batch}, arrive_every={arrive_every}"
        );
    }
}

/// Under a tight KV budget the scheduler must evict (re-prefilling the
/// victim later) yet still produce exactly the uncapped tokens, while
/// live KV bytes never exceed the cap (asserted every step above).
#[test]
fn kv_budget_evicts_without_changing_tokens() {
    let per_token = engine_on(1, SimdLevel::Scalar, GemmMode::Packed).kv_bytes_per_token();
    // Each request peaks at 8 cached tokens (5 prompt + 4 new − 1); a
    // 12-token cap admits two but cannot hold two at peak.
    let cap = 12 * per_token;
    let uncapped = scheduled_tokens(2, 0, None);
    let capped = scheduled_tokens(2, 0, Some(cap));
    let toks = |v: &Vec<(Vec<i32>, u32)>| v.iter().map(|(t, _)| t.clone()).collect::<Vec<_>>();
    assert_eq!(toks(&uncapped), toks(&capped), "eviction changed tokens");
    assert!(
        capped.iter().any(|(_, prefills)| *prefills > 1),
        "cap of {cap} bytes never forced an eviction; prefills: {:?}",
        capped.iter().map(|(_, p)| *p).collect::<Vec<_>>()
    );
}

// ---------------------------------------------------------------------------
// memmodel reconciliation: measured KV bytes == closed-form prediction
// ---------------------------------------------------------------------------

#[test]
fn measured_kv_bytes_match_memmodel_exactly() {
    let lib = Library::host_with_threads(1);
    let eng = InferenceEngine::init_random(lib.clone(), "tiny", 5).unwrap();
    let dims = HostBlockDims::from_model(eng.hyper());
    let layers = eng.hyper().layers as u64;
    assert_eq!(eng.kv_bytes_per_token(), layers * dims.kv_bytes_per_token_per_layer());

    let mut cache = eng.new_cache();
    eng.decode(&mut [DecodeEntry { cache: &mut cache, pending: &PROMPT }]).unwrap();
    let mut tokens = PROMPT.len() as u64;
    let mut last = 42i32;
    for _ in 0..4 {
        let next =
            eng.decode(&mut [DecodeEntry { cache: &mut cache, pending: &[last] }]).unwrap();
        last = next[0];
        tokens += 1;
        let want = dims.kv_cache_bytes(layers, tokens);
        assert_eq!(cache.bytes(), want, "cache accounting at {tokens} tokens");
        assert_eq!(
            lib.executor().memory().unwrap().kv_live_bytes,
            want,
            "executor meter at {tokens} tokens"
        );
    }
    // the budget↔tokens inverse the scheduler relies on
    assert_eq!(dims.kv_budget_tokens(layers, dims.kv_cache_bytes(layers, tokens)), tokens);

    drop(cache);
    let m = lib.executor().memory().unwrap();
    assert_eq!(m.kv_live_bytes, 0, "drop must release every metered byte");
    assert_eq!(m.kv_peak_bytes, dims.kv_cache_bytes(layers, tokens));
}

// ---------------------------------------------------------------------------
// checkpoints: both container formats serve identically to live params
// ---------------------------------------------------------------------------

#[test]
fn serves_from_both_checkpoint_formats() {
    let lib = library();
    let cfg = TrainConfig {
        model: "tiny".into(),
        optimizer: OptimizerKind::AdamA,
        backend: OptimBackend::Host,
        accum_steps: 2,
        chunk: 16384,
        ..TrainConfig::default()
    };
    let mut t = Trainer::new(lib.clone(), cfg).unwrap();
    let h = t.spec().hyper.clone();
    let mut corpus = MarkovCorpus::new(h.vocab, 77, 1);
    t.train_step(&corpus.minibatch(2, h.microbatch, h.seq)).unwrap();

    let dir = std::env::temp_dir().join(format!("adama_serve_ck_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let p1 = dir.join("params.ack1");
    let p2 = dir.join("state.ack2");
    t.save_checkpoint(&p1).unwrap();
    t.save_state(&p2, &[]).unwrap();

    let live: Vec<LayerParams> =
        t.params().iter().map(|p| LayerParams { flat: p.flat.clone() }).collect();
    let e0 = InferenceEngine::with_params(lib.clone(), "tiny", live).unwrap();
    let e1 = InferenceEngine::from_checkpoint(lib.clone(), "tiny", &p1).unwrap();
    let e2 = InferenceEngine::from_checkpoint(lib.clone(), "tiny", &p2).unwrap();

    let want = bits(&full_context_logits(&e0, &PROMPT));
    assert_eq!(want, bits(&full_context_logits(&e1, &PROMPT)), "ADAMACK1 round-trip");
    assert_eq!(want, bits(&full_context_logits(&e2, &PROMPT)), "ADAMACK2 round-trip");

    let _ = std::fs::remove_dir_all(&dir);
}
