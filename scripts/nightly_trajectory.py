#!/usr/bin/env python3
"""Append one dated row to the nightly trajectory table in EXPERIMENTS.md.

Usage: nightly_trajectory.py <fig7_output.txt> <BENCH_perf.json>

Pulls four headline numbers out of the nightly bench run:
  * E2.1 — the AdamA/Adam samples/s ratio at the largest swept N
    (last data row of the "Fig 7a" section of fig7_throughput's stdout);
  * E3 — the stash-vs-remat fwd+bwd pair speedup at budget=unlimited,
    4 threads (from BENCH_perf.json);
  * SIMD — the mean speedup_vs_scalar over the `simd_*` kernel rows and
    the dispatched level (from BENCH_perf.json);
  * GEMM — the packed-vs-naive engine speedup on the largest swept
    `gemm_*` shape (from the `speedup_packed_vs_naive` field);
  * E6 — the concurrent-fabric-vs-serial DP step-time speedup at the
    largest rank count (from the `dp_fabric_vs_serial` rows) and the
    async-vs-sync ZeRO-S1 issue speedup (`zero1_async_vs_sync` rows);
  * zoo — the `table2_opt_state_*` rows appended by table2_optimizers:
    how many ADAMA_OPT rules reconciled measured-vs-memmodel state bytes
    exactly, plus the smallest paper-scale state footprint;
  * serve — batched KV-cache decode throughput and p99 request latency
    at the largest swept batch width (`serve_decode` rows).

A bench that emitted **no rows** fails the run loudly (non-zero exit)
instead of appending an empty trajectory entry: a missing/empty
BENCH_perf.json or a Fig-7a section with no data rows means the nightly
is broken, and an "n/a | n/a | n/a" row would only hide that. Individual
secondary fields still degrade to "n/a" (a parse hiccup in one column is
a visible signal, not a red build). The table itself lives at the bottom
of EXPERIMENTS.md ("## Nightly trajectory").
"""

import datetime
import json
import platform
import re
import sys


def fig7_ratio(path):
    """Last data row of the Fig 7a section: (N, AdamA/Adam ratio)."""
    try:
        text = open(path, encoding="utf-8", errors="replace").read()
    except OSError as e:
        sys.exit(f"nightly_trajectory: cannot read fig7 output {path!r}: {e}")
    section = text.split("Fig 7a", 1)
    if len(section) < 2:
        sys.exit(f"nightly_trajectory: no 'Fig 7a' section in {path!r} — fig7 bench emitted no rows")
    best = None
    for line in section[1].splitlines():
        m = re.match(r"\s*(\d+)\s+[\d.]+\s+[\d.]+\s+([\d.]+)\s*$", line)
        if m:
            best = (int(m.group(1)), float(m.group(2)))
        elif line.startswith("==="):
            break  # next banner: stop at the end of the 7a section
    if best is None:
        sys.exit(f"nightly_trajectory: 'Fig 7a' section of {path!r} has no data rows — fig7 bench emitted no rows")
    return best


def bench_rows(path):
    try:
        with open(path, encoding="utf-8") as f:
            rows = json.load(f).get("results", [])
    except (OSError, ValueError) as e:
        sys.exit(f"nightly_trajectory: cannot read bench rows from {path!r}: {e}")
    if not rows:
        sys.exit(f"nightly_trajectory: {path!r} has an empty 'results' array — perf bench emitted no rows")
    return rows


def stash_speedup(rows):
    for r in rows:
        if (
            r.get("op") == "block_bwd_stash_vs_remat_small"
            and r.get("act_budget") == "unlimited"
            and r.get("threads") == 4
        ):
            return r.get("speedup_vs_remat")
    return None


def simd_speedup(rows):
    """Mean speedup_vs_scalar over the simd_* kernel rows + the level."""
    speedups, level = [], None
    for r in rows:
        op = r.get("op", "")
        if op.startswith("simd_") and "speedup_vs_scalar" in r:
            speedups.append(float(r["speedup_vs_scalar"]))
            level = r.get("simd", level)
    if not speedups:
        return None
    return (sum(speedups) / len(speedups), level)


def gemm_speedup(rows):
    """Packed-vs-naive speedup on the largest (by m·k·n) swept shape."""
    best = None
    for r in rows:
        op = r.get("op", "")
        if op.startswith("gemm_") and "speedup_packed_vs_naive" in r:
            size = int(r.get("m", 0)) * int(r.get("k", 0)) * int(r.get("n", 0))
            if best is None or size >= best[0]:
                best = (size, op[len("gemm_"):], float(r["speedup_packed_vs_naive"]))
    return best


def fabric_speedup(rows):
    """Fabric-vs-serial DP speedup at the largest recorded rank count."""
    best = None
    for r in rows:
        if r.get("op") == "dp_fabric_vs_serial" and "speedup_fabric_vs_serial" in r:
            ranks = int(r.get("ranks", 0))
            if best is None or ranks >= best[0]:
                best = (ranks, float(r["speedup_fabric_vs_serial"]))
    return best


def zero1_async_speedup(rows):
    """Async-vs-sync ZeRO-S1 issue speedup at the largest rank count."""
    best = None
    for r in rows:
        if r.get("op") == "zero1_async_vs_sync" and "speedup_async_vs_sync" in r:
            ranks = int(r.get("ranks", 0))
            if best is None or ranks >= best[0]:
                best = (ranks, float(r["speedup_async_vs_sync"]))
    return best


def serve_throughput(rows):
    """serve_decode tokens/s + p99 ms at the largest swept batch width."""
    best = None
    for r in rows:
        if r.get("op") == "serve_decode" and "tokens_per_sec" in r:
            batch = int(r.get("max_batch", 0))
            if best is None or batch >= best[0]:
                best = (batch, float(r["tokens_per_sec"]), float(r.get("latency_p99_ms", 0.0)))
    return best


def zoo_state(rows):
    """table2_opt_state_* rows: (#rules, #reconciled, min paper GB)."""
    total, ok, smallest = 0, 0, None
    for r in rows:
        op = r.get("op", "")
        if op.startswith("table2_opt_state_"):
            total += 1
            if r.get("reconciled"):
                ok += 1
            gb = float(r.get("paper_scale_state_bytes", 0)) / 2**30
            if smallest is None or gb < smallest[1]:
                smallest = (op[len("table2_opt_state_"):], gb)
    if total == 0:
        return None
    return (total, ok, smallest)


def main():
    if len(sys.argv) != 3:
        sys.exit(__doc__)
    fig7_path, bench_path = sys.argv[1], sys.argv[2]
    rows = bench_rows(bench_path)

    ratio = fig7_ratio(fig7_path)
    e2 = f"{ratio[1]:.3f} (N={ratio[0]})"
    stash = stash_speedup(rows)
    e3 = f"{stash:.2f}x" if stash else "n/a"
    simd = simd_speedup(rows)
    gemm = gemm_speedup(rows)
    fabric = fabric_speedup(rows)
    notes = [f"simd {simd[0]:.2f}x ({simd[1]})" if simd else "simd n/a"]
    if gemm:
        notes.append(f"gemm {gemm[2]:.2f}x ({gemm[1]})")
    if fabric:
        notes.append(f"fabric {fabric[1]:.2f}x (M={fabric[0]})")
    zasync = zero1_async_speedup(rows)
    if zasync:
        notes.append(f"async {zasync[1]:.2f}x (M={zasync[0]})")
    zoo = zoo_state(rows)
    if zoo:
        total, ok, (best_name, best_gb) = zoo
        notes.append(f"zoo {ok}/{total} reconciled (min {best_name} {best_gb:.2f} GB)")
    serve = serve_throughput(rows)
    if serve:
        notes.append(f"serve {serve[1]:.0f} tok/s p99 {serve[2]:.1f} ms (batch={serve[0]})")
    note = ", ".join(notes)

    threads = next((str(r["threads"]) for r in rows if "threads" in r), "?")
    date = datetime.date.today().isoformat()
    host = platform.machine() or "ci"
    row = f"| {date} | {host} | {threads} | {e2} | {e3} | {note} |\n"

    path = "EXPERIMENTS.md"
    text = open(path, encoding="utf-8").read()
    if "## Nightly trajectory" not in text:
        sys.exit("EXPERIMENTS.md has no '## Nightly trajectory' section")
    if not text.endswith("\n"):
        text += "\n"
    open(path, "w", encoding="utf-8").write(text + row)
    print("appended:", row.strip())


if __name__ == "__main__":
    main()
