"""L2 correctness: the per-layer artifact protocol equals monolithic jax.

The rust coordinator composes embed_fwd -> block_fwd^L -> head_loss, then
head_loss.dx -> block_bwd^L -> embed_bwd.  This test runs that exact
composition in python and checks every gradient against jax.grad of the
monolithic lm_loss — validating the decomposition the AOT artifacts freeze.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

CFG = model.CONFIGS["tiny"]


@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(0)
    params = model.init_params(CFG, key)
    tk = jax.random.randint(jax.random.PRNGKey(1), (CFG.microbatch, CFG.seq),
                            0, CFG.vocab)
    labels = jax.random.randint(jax.random.PRNGKey(2),
                                (CFG.microbatch, CFG.seq), 0, CFG.vocab)
    return params, tk, labels


def layerwise_grads(params, tokens, labels):
    """Exactly the L3 execution protocol over the artifact functions."""
    block_fwd = model.make_block_fwd(CFG)
    block_bwd = model.make_block_bwd(CFG)
    head_loss = model.make_head_loss(CFG)
    embed_bwd = model.make_embed_bwd(CFG)

    # forward, stashing each block's input (per-layer remat protocol)
    x = model.embed_fwd(tokens, params["embed.E"], params["embed.P"])
    stash = []
    for i in range(CFG.layers):
        blk = [params[f"block{i}.{n}"] for n in model.BLOCK_PARAM_NAMES]
        stash.append(x)
        x = block_fwd(x, *blk)
    loss, dx, dW = head_loss(x, params["head.W"], labels)

    grads = {"head.W": dW}
    for i in reversed(range(CFG.layers)):
        blk = [params[f"block{i}.{n}"] for n in model.BLOCK_PARAM_NAMES]
        out = block_bwd(stash[i], dx, *blk)
        dx = out[0]
        for n, g in zip(model.BLOCK_PARAM_NAMES, out[1:]):
            grads[f"block{i}.{n}"] = g
    dE, dP = embed_bwd(tokens, dx)
    grads["embed.E"] = dE
    grads["embed.P"] = dP
    return loss, grads


def test_layerwise_equals_monolithic(setup):
    params, tokens, labels = setup
    loss, grads = layerwise_grads(params, tokens, labels)

    mono_loss = model.lm_loss(CFG, params, tokens, labels)
    mono_grads = jax.grad(lambda p: model.lm_loss(CFG, p, tokens, labels))(
        params)

    np.testing.assert_allclose(loss, mono_loss, rtol=1e-5)
    assert set(grads) == set(mono_grads)
    for name in mono_grads:
        np.testing.assert_allclose(
            grads[name], mono_grads[name], rtol=2e-4, atol=2e-5,
            err_msg=f"grad mismatch for {name}")


def test_param_shapes_cover_all_blocks():
    shapes = dict(CFG.param_shapes())
    assert len(shapes) == 2 + 12 * CFG.layers + 1
    for i in range(CFG.layers):
        for n in model.BLOCK_PARAM_NAMES:
            assert f"block{i}.{n}" in shapes


def test_loss_decreases_under_sgd(setup):
    """Sanity: the model is actually trainable (few hand-rolled steps)."""
    params, tokens, labels = setup
    params = dict(params)
    loss0 = None
    for _ in range(5):
        loss, grads = jax.value_and_grad(
            lambda p: model.lm_loss(CFG, p, tokens, labels))(params)
        if loss0 is None:
            loss0 = loss
        params = {k: params[k] - 0.1 * grads[k] for k in params}
    loss_end = model.lm_loss(CFG, params, tokens, labels)
    assert loss_end < loss0


def test_head_eval_counts(setup):
    params, tokens, labels = setup
    head_eval = model.make_head_eval(CFG)
    x = model.lm_forward(CFG, params, tokens)  # logits
    # head_eval takes pre-head activations; rebuild them
    xact = model.embed_fwd(tokens, params["embed.E"], params["embed.P"])
    for i in range(CFG.layers):
        blk = [params[f"block{i}.{n}"] for n in model.BLOCK_PARAM_NAMES]
        xact = model.block_apply(xact, blk, CFG.heads)
    loss, ncorrect = head_eval(xact, params["head.W"], labels)
    assert 0 <= int(ncorrect) <= CFG.microbatch * CFG.seq
    assert float(loss) > 0


def test_mlp_train_grads_match_autodiff():
    cfg = model.MLP_CONFIGS["tiny"]
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 6)
    x = jax.random.normal(ks[0], (cfg.microbatch, cfg.features))
    labels = jax.random.randint(ks[1], (cfg.microbatch,), 0, cfg.classes)
    W1 = 0.1 * jax.random.normal(ks[2], (cfg.features, cfg.hidden))
    b1 = jnp.zeros((cfg.hidden,))
    W2 = 0.1 * jax.random.normal(ks[3], (cfg.hidden, cfg.classes))
    b2 = jnp.zeros((cfg.classes,))

    out = model.make_mlp_train(cfg)(x, labels, W1, b1, W2, b2)
    loss, grads = out[0], out[1:]

    def loss_fn(W1, b1, W2, b2):
        logits = model.mlp_apply(x, W1, b1, W2, b2)
        logp = jax.nn.log_softmax(logits, axis=-1)
        return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))

    want = jax.grad(loss_fn, argnums=(0, 1, 2, 3))(W1, b1, W2, b2)
    np.testing.assert_allclose(loss, loss_fn(W1, b1, W2, b2), rtol=1e-6)
    for a, b in zip(grads, want):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)
