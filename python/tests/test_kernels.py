"""L1 correctness: every Pallas kernel vs the pure-jnp oracle.

hypothesis sweeps chunk sizes (multiples of LANES*BLOCK_ROWS), values and
scalars; assert_allclose against ref.py is THE correctness signal for the
optimizer hot path that the rust coordinator executes through PJRT.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import adama, ref

GRAIN = adama.LANES * adama.BLOCK_ROWS  # smallest legal chunk


def vec(rng, n, scale=3.0):
    return jnp.asarray(rng.standard_normal(n) * scale, jnp.float32)


def chunks():
    return st.integers(min_value=1, max_value=6).map(lambda k: k * GRAIN)


@settings(max_examples=20, deadline=None)
@given(chunks(), st.integers(0, 2**31 - 1),
       st.floats(1e-3, 1.0), st.floats(0.0, 0.999))
def test_adama_accumulate_matches_ref(chunk, seed, gscale, beta1):
    rng = np.random.default_rng(seed)
    m, v, g = vec(rng, chunk), np.abs(vec(rng, chunk)), vec(rng, chunk)
    s = jnp.array([gscale], jnp.float32)
    got_m, got_v = adama.adama_accumulate(m, v, g, s, beta1=beta1)
    ref_m, ref_v = ref.adama_accumulate(m, v, g, s[0], beta1=beta1)
    np.testing.assert_allclose(got_m, ref_m, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(got_v, ref_v, rtol=1e-6, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(chunks(), st.integers(0, 2**31 - 1),
       st.floats(0.1, 1.0), st.floats(0.1, 8.0))
def test_adama_decay_matches_ref(chunk, seed, mscale, vscale):
    rng = np.random.default_rng(seed)
    m, v = vec(rng, chunk), np.abs(vec(rng, chunk))
    ms = jnp.array([mscale], jnp.float32)
    vs = jnp.array([vscale], jnp.float32)
    got_m, got_v = adama.adama_decay(m, v, ms, vs)
    ref_m, ref_v = ref.adama_decay(m, v, ms[0], vs[0])
    np.testing.assert_allclose(got_m, ref_m, rtol=1e-6)
    np.testing.assert_allclose(got_v, ref_v, rtol=1e-6)


@settings(max_examples=15, deadline=None)
@given(chunks(), st.integers(0, 2**31 - 1),
       st.floats(1e-5, 1e-1), st.integers(1, 1000))
def test_adam_update_matches_ref(chunk, seed, lr, t):
    rng = np.random.default_rng(seed)
    p, m = vec(rng, chunk), vec(rng, chunk)
    v = np.abs(vec(rng, chunk))
    bc1 = 1.0 - ref.BETA1 ** t
    bc2 = 1.0 - ref.BETA2 ** t
    sc = jnp.array([lr, bc1, bc2], jnp.float32)
    got = adama.adam_update(p, m, v, sc)
    want = ref.adam_update(p, m, v, sc[0], sc[1], sc[2])
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(chunks(), st.integers(0, 2**31 - 1), st.floats(1e-5, 1e-1))
def test_adam_full_step_matches_ref(chunk, seed, lr):
    rng = np.random.default_rng(seed)
    p, m, g = vec(rng, chunk), vec(rng, chunk), vec(rng, chunk)
    v = np.abs(vec(rng, chunk))
    sc = jnp.array([lr, 1.0 - ref.BETA1, 1.0 - ref.BETA2], jnp.float32)
    got = adama.adam_full_step(p, m, v, g, sc)
    want = ref.adam_full_step(p, m, v, g, sc[0], sc[1], sc[2])
    for a, b in zip(got, want):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(chunks(), st.integers(0, 2**31 - 1), st.floats(1e-3, 1.0))
def test_grad_accumulate_matches_ref(chunk, seed, gscale):
    rng = np.random.default_rng(seed)
    acc, g = vec(rng, chunk), vec(rng, chunk)
    s = jnp.array([gscale], jnp.float32)
    got = adama.grad_accumulate(acc, g, s)
    np.testing.assert_allclose(got, ref.grad_accumulate(acc, g, s[0]),
                               rtol=1e-6, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(chunks(), st.integers(0, 2**31 - 1),
       st.floats(1e-5, 1e-2), st.floats(0.05, 1.0))
def test_adama_acc_update_matches_ref(chunk, seed, lr, gscale):
    rng = np.random.default_rng(seed)
    p, m, g = vec(rng, chunk), vec(rng, chunk), vec(rng, chunk)
    v = np.abs(vec(rng, chunk))
    s = jnp.array([gscale], jnp.float32)
    sc = jnp.array([lr, 1.0 - ref.BETA1, 1.0 - ref.BETA2], jnp.float32)
    got = adama.adama_acc_update(p, m, v, g, s, sc)
    want = ref.adama_acc_update(p, m, v, g, s[0], sc[0], sc[1], sc[2])
    for a, b in zip(got, want):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_block_rows_ablation_same_result():
    """Block shape is a pure perf knob: results identical across tilings."""
    rng = np.random.default_rng(7)
    chunk = 4 * GRAIN
    m, v, g = vec(rng, chunk), np.abs(vec(rng, chunk)), vec(rng, chunk)
    s = jnp.array([0.5], jnp.float32)
    base = adama.adama_accumulate(m, v, g, s, block_rows=adama.BLOCK_ROWS)
    for br in (8, 32, 128):
        other = adama.adama_accumulate(m, v, g, s, block_rows=br)
        np.testing.assert_allclose(base[0], other[0], rtol=1e-7)
        np.testing.assert_allclose(base[1], other[1], rtol=1e-7)


def test_chunk_must_be_lane_aligned():
    rng = np.random.default_rng(0)
    bad = vec(rng, 100)
    with pytest.raises(ValueError):
        adama.adama_accumulate(bad, bad, bad, jnp.array([1.0], jnp.float32))


@settings(max_examples=12, deadline=None)
@given(chunks(), st.integers(0, 2**31 - 1),
       st.floats(0.05, 1.0), st.floats(0.5, 1.0), st.floats(0.5, 8.0))
def test_adama_decay_acc_matches_ref(chunk, seed, gscale, mscale, vscale):
    rng = np.random.default_rng(seed)
    m, v, g = vec(rng, chunk), np.abs(vec(rng, chunk)), vec(rng, chunk)
    sc = jnp.array([gscale, mscale, vscale], jnp.float32)
    got_m, got_v = adama.adama_decay_acc(m, v, g, sc)
    ref_m, ref_v = ref.adama_decay_acc(m, v, g, sc[0], sc[1], sc[2])
    np.testing.assert_allclose(got_m, ref_m, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(got_v, ref_v, rtol=1e-5, atol=1e-6)


def test_decay_acc_equals_decay_then_acc():
    rng = np.random.default_rng(3)
    chunk = 2 * GRAIN
    m, v, g = vec(rng, chunk), np.abs(vec(rng, chunk)), vec(rng, chunk)
    sc = jnp.array([0.25, ref.BETA1, ref.BETA2], jnp.float32)
    fused = adama.adama_decay_acc(m, v, g, sc)
    m2, v2 = ref.adama_decay(m, v, sc[1], sc[2])
    seq = ref.adama_accumulate(m2, v2, g, sc[0])
    np.testing.assert_allclose(fused[0], seq[0], rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(fused[1], seq[1], rtol=1e-5, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(chunks(), st.integers(0, 2**31 - 1),
       st.floats(1e-5, 1e-2), st.floats(0.0, 0.2))
def test_adamw_update_matches_ref(chunk, seed, lr, wd):
    rng = np.random.default_rng(seed)
    p, m = vec(rng, chunk), vec(rng, chunk)
    v = np.abs(vec(rng, chunk))
    sc = jnp.array([lr, 0.1, 0.001, wd], jnp.float32)
    got = adama.adamw_update(p, m, v, sc)
    want = ref.adamw_update(p, m, v, sc[0], sc[1], sc[2], sc[3])
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(chunks(), st.integers(0, 2**31 - 1),
       st.floats(0.05, 1.0), st.floats(0.0, 0.99))
def test_sgdm_kernels_match_ref(chunk, seed, gscale, mu):
    rng = np.random.default_rng(seed)
    u, g, p = vec(rng, chunk), vec(rng, chunk), vec(rng, chunk)
    sc2 = jnp.array([gscale, mu], jnp.float32)
    got = adama.sgdm_decay_acc(u, g, sc2)
    np.testing.assert_allclose(got, ref.sgdm_decay_acc(u, g, sc2[0], sc2[1]),
                               rtol=1e-6, atol=1e-6)
    s1 = jnp.array([gscale], jnp.float32)
    got = adama.sgdm_acc(u, g, s1)
    np.testing.assert_allclose(got, ref.sgdm_acc(u, g, s1[0]),
                               rtol=1e-6, atol=1e-6)
    lrwd = jnp.array([1e-2, 0.01], jnp.float32)
    got = adama.sgdm_update(p, u, lrwd)
    np.testing.assert_allclose(got, ref.sgdm_update(p, u, lrwd[0], lrwd[1]),
                               rtol=1e-6, atol=1e-6)
