"""AOT pipeline tests: manifest structure + HLO text well-formedness."""
import json
import os

import jax.numpy as jnp
import pytest

from compile import aot, model

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("run `make artifacts` first")
    with open(path) as f:
        return json.load(f)


def test_hyper_matches_ref(manifest):
    from compile.kernels import ref
    assert manifest["hyper"]["beta1"] == ref.BETA1
    assert manifest["hyper"]["beta2"] == ref.BETA2
    assert manifest["hyper"]["eps"] == ref.EPS


def test_all_artifact_files_exist(manifest):
    groups = [manifest["common"]]
    groups += [c["artifacts"] for c in manifest["configs"].values()]
    groups += [c["artifacts"] for c in manifest["mlp_configs"].values()]
    n = 0
    for group in groups:
        for name, entry in group.items():
            path = os.path.join(ART, entry["file"])
            assert os.path.exists(path), f"missing {entry['file']}"
            with open(path) as f:
                head = f.read(200)
            assert "HloModule" in head, f"{name} is not HLO text"
            n += 1
    assert n >= 30


def test_block_bwd_io_counts(manifest):
    for cname, cfg in manifest["configs"].items():
        e = cfg["artifacts"]["block_bwd"]
        # x, dy + 12 params in; dx + 12 dparams out
        assert len(e["inputs"]) == 14, cname
        assert len(e["outputs"]) == 13, cname


def test_chunk_kernel_shapes(manifest):
    for c in manifest["chunk_sizes"]:
        acc = manifest["common"][f"adama_acc_{c}"]
        assert acc["inputs"][0]["shape"] == [c]
        assert acc["inputs"][3]["shape"] == [1]
        assert [o["shape"] for o in acc["outputs"]] == [[c], [c]]
        upd = manifest["common"][f"adam_update_{c}"]
        assert upd["inputs"][3]["shape"] == [3]


def test_lower_artifact_roundtrip(tmp_path):
    """Lowering a fresh trivial fn produces parseable HLO + correct specs."""
    def f(x, y):
        return (x @ y + 1.0,)

    spec = jnp.zeros((4, 4), jnp.float32)
    entry = aot.lower_artifact(f, [spec, spec], str(tmp_path), "t/f.hlo.txt")
    assert entry["inputs"][0] == {"shape": [4, 4], "dtype": "f32"}
    text = (tmp_path / "t" / "f.hlo.txt").read_text()
    assert "HloModule" in text and "dot" in text


def test_param_shapes_match_manifest(manifest):
    for name, entry in manifest["configs"].items():
        cfg = model.CONFIGS[name]
        want = [[n, list(s)] for n, s in cfg.param_shapes()]
        assert entry["param_shapes"] == want
