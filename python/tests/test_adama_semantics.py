"""Optimizer-math oracles for the paper's invariants (DESIGN.md §5).

These python-level proofs-by-execution mirror the rust integration tests:
  1. AdamA(N=1) == Adam(N=1) bitwise-ish (same float ops modulo assoc).
  2. m_t identical for any N; v_t differs exactly by sum-of-squares.
  3. Distributed AdamA (M workers x N micro-batches, Eq. 5-8) ==
     single-device AdamA with N*M micro-batches.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref

B1, B2 = ref.BETA1, ref.BETA2


def adam_minibatch(m, v, grads):
    """Standard Adam accumulation over micro-batch grads (Alg. 1 blue)."""
    n = len(grads)
    gsum = sum(g / n for g in grads)
    return B1 * m + (1 - B1) * gsum, B2 * v + (1 - B2) * gsum * gsum


def adama_minibatch(m, v, grads, vscale=B2):
    """AdamA accumulation (Alg. 2): decay once, integrate each micro-grad."""
    n = len(grads)
    m, v = ref.adama_decay(m, v, B1, vscale)
    for g in grads:
        m, v = ref.adama_accumulate(m, v, g, 1.0 / n)
    return np.asarray(m), np.asarray(v)


def rand_grads(seed, n, d=512):
    rng = np.random.default_rng(seed)
    return [rng.standard_normal(d).astype(np.float32) for _ in range(n)]


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_n1_equivalence(seed):
    (g,) = rand_grads(seed, 1)
    m = np.zeros_like(g)
    v = np.zeros_like(g)
    am, av = adam_minibatch(m, v, [g])
    aam, aav = adama_minibatch(m, v, [g])
    np.testing.assert_allclose(am, aam, rtol=1e-7)
    np.testing.assert_allclose(av, aav, rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([2, 4, 8]))
def test_m_identical_v_sum_of_squares(seed, n):
    grads = rand_grads(seed, n)
    rng = np.random.default_rng(seed + 1)
    m0 = rng.standard_normal(512).astype(np.float32)
    v0 = np.abs(rng.standard_normal(512)).astype(np.float32)

    am, av = adam_minibatch(m0, v0, grads)
    aam, aav = adama_minibatch(m0, v0, grads)

    np.testing.assert_allclose(am, aam, rtol=1e-5, atol=1e-7)
    want_v = B2 * v0 + (1 - B2) * sum((g / n) ** 2 for g in grads)
    np.testing.assert_allclose(aav, want_v, rtol=1e-5, atol=1e-8)
    # and v really is different from Adam's (Σg)² when N>1
    assert not np.allclose(aav, av, rtol=1e-3, atol=1e-9)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1),
       st.sampled_from([(2, 2), (2, 4), (4, 2), (4, 4)]))
def test_distributed_equals_single_nm(seed, mn):
    """Eq. 5-8: M workers x N micro-batches == single device x N*M."""
    M, N = mn
    grads = rand_grads(seed, M * N)
    rng = np.random.default_rng(seed + 2)
    m0 = rng.standard_normal(512).astype(np.float32)
    v0 = np.abs(rng.standard_normal(512)).astype(np.float32)

    # single device, NM micro-batches
    sm, sv = adama_minibatch(m0, v0, grads)

    # M workers, N micro-batches each, Eq. 5-6 local updates
    local = []
    for w in range(M):
        mine = grads[w * N:(w + 1) * N]
        m, v = ref.adama_decay(m0, v0, B1, M * B2)  # vscale = M*beta2
        for g in mine:
            # worker-local gscale is 1/N (paper Eq. 5-6); the all-reduce's
            # /M (for m) and /M^2 (for v) supply the remaining scaling.
            m, v = ref.adama_accumulate(m, v, g, 1.0 / N)
        local.append((np.asarray(m), np.asarray(v)))

    # all-reduce: mean of m, sum of v divided by M^2 (Eq. 7-8)
    gm = sum(l[0] for l in local) / M
    gv = sum(l[1] for l in local) / (M * M)

    np.testing.assert_allclose(gm, sm, rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(gv, sv, rtol=1e-5, atol=1e-8)


def test_fig4_coefficient_near_one_noise_dominated():
    """sqrt(v̂_adam)/sqrt(v̂_adama) ≈ 1 when micro-grad noise dominates.

    Fig. 4's "deviation within 1%" is a property of the *realistic* SGD
    regime where per-micro-batch gradient noise σ dominates the mini-batch
    mean μ: then E[(Σg/n)²] ≈ σ²/n ≈ E[Σ(g/n)²].  In the mean-dominated
    limit the ratio instead approaches sqrt(n) — which is exactly why
    AdamA != Adam pointwise yet matches it in convergence.  Both regimes
    are swept by benches/fig4_coefficient.rs.
    """
    rng = np.random.default_rng(0)
    d, n, steps = 1024, 8, 50
    m_a = v_a = m_b = v_b = np.zeros(d, np.float32)
    base = 0.05 * rng.standard_normal(d).astype(np.float32)
    for t in range(1, steps + 1):
        grads = [base + rng.standard_normal(d).astype(np.float32)
                 for _ in range(n)]
        m_a, v_a = adam_minibatch(m_a, v_a, grads)
        m_b, v_b = adama_minibatch(m_b, v_b, grads)
        bc2 = 1 - B2 ** t
        coeff = np.sqrt(v_a / bc2 + 1e-12) / np.sqrt(v_b / bc2 + 1e-12)
    # after burn-in the mean coefficient sits within a few % of 1.0
    assert 0.9 < float(np.mean(coeff)) < 1.1


def test_fig4_coefficient_mean_dominated_limit():
    """In the fully-correlated limit the coefficient approaches sqrt(n)."""
    rng = np.random.default_rng(1)
    d, n = 1024, 8
    g = rng.standard_normal(d).astype(np.float32)
    z = np.zeros(d, np.float32)
    _, v_a = adam_minibatch(z, z, [g] * n)
    _, v_b = adama_minibatch(z, z, [g] * n)
    coeff = np.sqrt(v_a / (v_b + 1e-20))
    np.testing.assert_allclose(coeff, np.sqrt(n), rtol=1e-3)
