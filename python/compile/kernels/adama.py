"""Layer-1 Pallas kernels: the AdamA optimizer hot-spot.

The paper's core op is the per-layer, per-micro-batch integration of a raw
gradient into the Adam optimizer states (Alg. 2):

    m += (1 - beta1) * (g / N)
    v += (1 - beta2) * (g / N)^2

followed by an immediate release of the gradient buffer.  The rust
coordinator (L3) flattens every parameter tensor into fixed-size chunks and
calls these kernels chunk-by-chunk, mirroring fused-Adam-over-flat-buffer
designs (DeepSpeed / apex FusedAdam).

TPU adaptation (DESIGN.md §Hardware-Adaptation): the update is a pure
elementwise (VPU) op, so each chunk is viewed as a (rows, 128) lane-aligned
matrix and tiled into (BLOCK_ROWS, 128) VMEM blocks via BlockSpec; the grid
streams HBM->VMEM block-by-block which is where double-buffering happens on
real hardware.  ``interpret=True`` everywhere: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and interpret-mode lowers to plain HLO that the
rust runtime runs bit-for-bit.

All kernels operate on float32 flat chunks of length ``chunk`` (a multiple
of LANES).  Runtime scalars (gscale, lr, bias corrections, decay factors)
arrive as shape-(1,) f32 inputs so the rust side can drive LR schedules and
the distributed M*beta2 scaling (Eq. 6) without re-AOT-ing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

LANES = 128           # TPU lane width; last dim of every block
# rows per VMEM block. 256*128*4B = 128 KiB per operand; with <=6 operands
# resident that is <1 MiB of VMEM — comfortably double-bufferable in 16 MiB.
# (Perf pass: raised from 64; in interpret mode the grid lowers to a
# sequential HLO while-loop, so fewer/larger blocks cut loop overhead.)
BLOCK_ROWS = 256

BETA1 = ref.BETA1
BETA2 = ref.BETA2
EPS = ref.EPS


def _grid_rows(chunk: int, block_rows: int):
    if chunk % LANES != 0:
        raise ValueError(f"chunk {chunk} must be a multiple of {LANES}")
    rows = chunk // LANES
    block_rows = min(block_rows, rows)  # small chunks: one block, grid 1
    if rows % block_rows != 0:
        raise ValueError(f"rows {rows} must be a multiple of {block_rows}")
    return rows, rows // block_rows, block_rows


def _vec_spec(block_rows):
    """BlockSpec for a (rows, LANES) operand tiled along rows."""
    return pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))


def _scalar_spec():
    """BlockSpec for a shape-(1,) runtime scalar broadcast to every block."""
    return pl.BlockSpec((1,), lambda i: (0,))


# ---------------------------------------------------------------------------
# kernel bodies
# ---------------------------------------------------------------------------

def _adama_accumulate_kernel(m_ref, v_ref, g_ref, s_ref, mo_ref, vo_ref,
                             *, beta1, beta2):
    sg = g_ref[...] * s_ref[0]
    mo_ref[...] = m_ref[...] + (1.0 - beta1) * sg
    vo_ref[...] = v_ref[...] + (1.0 - beta2) * sg * sg


def _adama_decay_acc_kernel(m_ref, v_ref, g_ref, sc_ref, mo_ref, vo_ref,
                            *, beta1, beta2):
    # fused mini-batch-start decay + first micro-batch accumulation
    # (perf pass: saves one full HBM round-trip over m and v per step).
    # sc = [gscale, mscale, vscale]
    sg = g_ref[...] * sc_ref[0]
    mo_ref[...] = m_ref[...] * sc_ref[1] + (1.0 - beta1) * sg
    vo_ref[...] = v_ref[...] * sc_ref[2] + (1.0 - beta2) * sg * sg


def _adama_decay_kernel(m_ref, v_ref, ms_ref, vs_ref, mo_ref, vo_ref):
    mo_ref[...] = m_ref[...] * ms_ref[0]
    vo_ref[...] = v_ref[...] * vs_ref[0]


def _adam_update_kernel(p_ref, m_ref, v_ref, sc_ref, po_ref, *, eps):
    lr, bc1, bc2 = sc_ref[0], sc_ref[1], sc_ref[2]
    mhat = m_ref[...] / bc1
    vhat = v_ref[...] / bc2
    po_ref[...] = p_ref[...] - lr * mhat / (jnp.sqrt(vhat) + eps)


def _adam_full_step_kernel(p_ref, m_ref, v_ref, g_ref, sc_ref,
                           po_ref, mo_ref, vo_ref, *, beta1, beta2, eps):
    lr, bc1, bc2 = sc_ref[0], sc_ref[1], sc_ref[2]
    g = g_ref[...]
    m2 = beta1 * m_ref[...] + (1.0 - beta1) * g
    v2 = beta2 * v_ref[...] + (1.0 - beta2) * g * g
    mo_ref[...] = m2
    vo_ref[...] = v2
    po_ref[...] = p_ref[...] - lr * (m2 / bc1) / (jnp.sqrt(v2 / bc2) + eps)


def _grad_accumulate_kernel(a_ref, g_ref, s_ref, ao_ref):
    ao_ref[...] = a_ref[...] + g_ref[...] * s_ref[0]


def _adama_acc_update_kernel(p_ref, m_ref, v_ref, g_ref, s_ref, sc_ref,
                             po_ref, mo_ref, vo_ref, *, beta1, beta2, eps):
    sg = g_ref[...] * s_ref[0]
    m2 = m_ref[...] + (1.0 - beta1) * sg
    v2 = v_ref[...] + (1.0 - beta2) * sg * sg
    lr, bc1, bc2 = sc_ref[0], sc_ref[1], sc_ref[2]
    mo_ref[...] = m2
    vo_ref[...] = v2
    po_ref[...] = p_ref[...] - lr * (m2 / bc1) / (jnp.sqrt(v2 / bc2) + eps)


# ---------------------------------------------------------------------------
# flat-chunk entry points (what L2/aot.py lowers)
# ---------------------------------------------------------------------------

def _as2d(x):
    return x.reshape(-1, LANES)


def adama_accumulate(m, v, g, gscale, *, beta1=BETA1, beta2=BETA2,
                     block_rows=BLOCK_ROWS):
    """(m, v, g: f32[chunk]; gscale: f32[1]) -> (m', v')."""
    chunk = m.shape[0]
    rows, grid, block_rows = _grid_rows(chunk, block_rows)
    out = pl.pallas_call(
        functools.partial(_adama_accumulate_kernel, beta1=beta1, beta2=beta2),
        grid=(grid,),
        in_specs=[_vec_spec(block_rows)] * 3 + [_scalar_spec()],
        out_specs=[_vec_spec(block_rows)] * 2,
        out_shape=[jax.ShapeDtypeStruct((rows, LANES), jnp.float32)] * 2,
        interpret=True,
    )(_as2d(m), _as2d(v), _as2d(g), gscale)
    return out[0].reshape(chunk), out[1].reshape(chunk)


def adama_decay_acc(m, v, g, scalars, *, beta1=BETA1, beta2=BETA2,
                    block_rows=BLOCK_ROWS):
    """(m, v, g: f32[chunk]; scalars: f32[3] = [gscale, mscale, vscale])
    -> (m', v'). Fused decay + accumulate for the first micro-batch."""
    chunk = m.shape[0]
    rows, grid, block_rows = _grid_rows(chunk, block_rows)
    out = pl.pallas_call(
        functools.partial(_adama_decay_acc_kernel, beta1=beta1, beta2=beta2),
        grid=(grid,),
        in_specs=[_vec_spec(block_rows)] * 3
        + [pl.BlockSpec((3,), lambda i: (0,))],
        out_specs=[_vec_spec(block_rows)] * 2,
        out_shape=[jax.ShapeDtypeStruct((rows, LANES), jnp.float32)] * 2,
        interpret=True,
    )(_as2d(m), _as2d(v), _as2d(g), scalars)
    return out[0].reshape(chunk), out[1].reshape(chunk)


def adama_decay(m, v, mscale, vscale, *, block_rows=BLOCK_ROWS):
    """(m, v: f32[chunk]; mscale, vscale: f32[1]) -> (m', v')."""
    chunk = m.shape[0]
    rows, grid, block_rows = _grid_rows(chunk, block_rows)
    out = pl.pallas_call(
        _adama_decay_kernel,
        grid=(grid,),
        in_specs=[_vec_spec(block_rows)] * 2 + [_scalar_spec()] * 2,
        out_specs=[_vec_spec(block_rows)] * 2,
        out_shape=[jax.ShapeDtypeStruct((rows, LANES), jnp.float32)] * 2,
        interpret=True,
    )(_as2d(m), _as2d(v), mscale, vscale)
    return out[0].reshape(chunk), out[1].reshape(chunk)


def adam_update(p, m, v, scalars, *, eps=EPS, block_rows=BLOCK_ROWS):
    """(p, m, v: f32[chunk]; scalars: f32[3] = [lr, bc1, bc2]) -> p'."""
    chunk = p.shape[0]
    rows, grid, block_rows = _grid_rows(chunk, block_rows)
    out = pl.pallas_call(
        functools.partial(_adam_update_kernel, eps=eps),
        grid=(grid,),
        in_specs=[_vec_spec(block_rows)] * 3
        + [pl.BlockSpec((3,), lambda i: (0,))],
        out_specs=_vec_spec(block_rows),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
        interpret=True,
    )(_as2d(p), _as2d(m), _as2d(v), scalars)
    return out.reshape(chunk)


def adam_full_step(p, m, v, g, scalars, *, beta1=BETA1, beta2=BETA2, eps=EPS,
                   block_rows=BLOCK_ROWS):
    """Baseline Adam step. scalars: f32[3] = [lr, bc1, bc2]."""
    chunk = p.shape[0]
    rows, grid, block_rows = _grid_rows(chunk, block_rows)
    out = pl.pallas_call(
        functools.partial(_adam_full_step_kernel, beta1=beta1, beta2=beta2,
                          eps=eps),
        grid=(grid,),
        in_specs=[_vec_spec(block_rows)] * 4
        + [pl.BlockSpec((3,), lambda i: (0,))],
        out_specs=[_vec_spec(block_rows)] * 3,
        out_shape=[jax.ShapeDtypeStruct((rows, LANES), jnp.float32)] * 3,
        interpret=True,
    )(_as2d(p), _as2d(m), _as2d(v), _as2d(g), scalars)
    return tuple(o.reshape(chunk) for o in out)


def grad_accumulate(acc, g, gscale, *, block_rows=BLOCK_ROWS):
    """(acc, g: f32[chunk]; gscale: f32[1]) -> acc'."""
    chunk = acc.shape[0]
    rows, grid, block_rows = _grid_rows(chunk, block_rows)
    out = pl.pallas_call(
        _grad_accumulate_kernel,
        grid=(grid,),
        in_specs=[_vec_spec(block_rows)] * 2 + [_scalar_spec()],
        out_specs=_vec_spec(block_rows),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
        interpret=True,
    )(_as2d(acc), _as2d(g), gscale)
    return out.reshape(chunk)


def adama_acc_update(p, m, v, g, gscale, scalars, *, beta1=BETA1, beta2=BETA2,
                     eps=EPS, block_rows=BLOCK_ROWS):
    """Fused accumulate-then-update for the final micro-batch (perf path)."""
    chunk = p.shape[0]
    rows, grid, block_rows = _grid_rows(chunk, block_rows)
    out = pl.pallas_call(
        functools.partial(_adama_acc_update_kernel, beta1=beta1, beta2=beta2,
                          eps=eps),
        grid=(grid,),
        in_specs=[_vec_spec(block_rows)] * 4
        + [_scalar_spec(), pl.BlockSpec((3,), lambda i: (0,))],
        out_specs=[_vec_spec(block_rows)] * 3,
        out_shape=[jax.ShapeDtypeStruct((rows, LANES), jnp.float32)] * 3,
        interpret=True,
    )(_as2d(p), _as2d(m), _as2d(v), _as2d(g), gscale, scalars)
    return tuple(o.reshape(chunk) for o in out)


# ---------------------------------------------------------------------------
# §5 extensions: AdamA generalises to any momentum-based optimizer.
# AdamW-A (decoupled weight decay) and SGDM-A (momentum SGD accumulation).
# ---------------------------------------------------------------------------

def _adamw_update_kernel(p_ref, m_ref, v_ref, sc_ref, po_ref, *, eps):
    # sc = [lr, bc1, bc2, wd]; decoupled weight decay (AdamW)
    lr, bc1, bc2, wd = sc_ref[0], sc_ref[1], sc_ref[2], sc_ref[3]
    mhat = m_ref[...] / bc1
    vhat = v_ref[...] / bc2
    p = p_ref[...]
    po_ref[...] = p - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * p)


def _sgdm_decay_acc_kernel(u_ref, g_ref, sc_ref, uo_ref):
    # sc = [gscale, mu]; first-micro-batch fused decay + accumulate:
    # u = mu*u + gscale*g   (heavy-ball momentum accumulation)
    uo_ref[...] = u_ref[...] * sc_ref[1] + g_ref[...] * sc_ref[0]


def _sgdm_acc_kernel(u_ref, g_ref, s_ref, uo_ref):
    uo_ref[...] = u_ref[...] + g_ref[...] * s_ref[0]


def _sgdm_update_kernel(p_ref, u_ref, sc_ref, po_ref):
    # sc = [lr, wd]
    p = p_ref[...]
    po_ref[...] = p - sc_ref[0] * (u_ref[...] + sc_ref[1] * p)


def adamw_update(p, m, v, scalars, *, eps=EPS, block_rows=BLOCK_ROWS):
    """(p, m, v: f32[chunk]; scalars: f32[4] = [lr, bc1, bc2, wd]) -> p'."""
    chunk = p.shape[0]
    rows, grid, block_rows = _grid_rows(chunk, block_rows)
    out = pl.pallas_call(
        functools.partial(_adamw_update_kernel, eps=eps),
        grid=(grid,),
        in_specs=[_vec_spec(block_rows)] * 3
        + [pl.BlockSpec((4,), lambda i: (0,))],
        out_specs=_vec_spec(block_rows),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
        interpret=True,
    )(_as2d(p), _as2d(m), _as2d(v), scalars)
    return out.reshape(chunk)


def sgdm_decay_acc(u, g, scalars, *, block_rows=BLOCK_ROWS):
    """(u, g: f32[chunk]; scalars: f32[2] = [gscale, mu]) -> u'."""
    chunk = u.shape[0]
    rows, grid, block_rows = _grid_rows(chunk, block_rows)
    out = pl.pallas_call(
        _sgdm_decay_acc_kernel,
        grid=(grid,),
        in_specs=[_vec_spec(block_rows)] * 2
        + [pl.BlockSpec((2,), lambda i: (0,))],
        out_specs=_vec_spec(block_rows),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
        interpret=True,
    )(_as2d(u), _as2d(g), scalars)
    return out.reshape(chunk)


def sgdm_acc(u, g, gscale, *, block_rows=BLOCK_ROWS):
    """(u, g: f32[chunk]; gscale: f32[1]) -> u'."""
    chunk = u.shape[0]
    rows, grid, block_rows = _grid_rows(chunk, block_rows)
    out = pl.pallas_call(
        _sgdm_acc_kernel,
        grid=(grid,),
        in_specs=[_vec_spec(block_rows)] * 2 + [_scalar_spec()],
        out_specs=_vec_spec(block_rows),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
        interpret=True,
    )(_as2d(u), _as2d(g), gscale)
    return out.reshape(chunk)


def sgdm_update(p, u, scalars, *, block_rows=BLOCK_ROWS):
    """(p, u: f32[chunk]; scalars: f32[2] = [lr, wd]) -> p'."""
    chunk = p.shape[0]
    rows, grid, block_rows = _grid_rows(chunk, block_rows)
    out = pl.pallas_call(
        _sgdm_update_kernel,
        grid=(grid,),
        in_specs=[_vec_spec(block_rows)] * 2
        + [pl.BlockSpec((2,), lambda i: (0,))],
        out_specs=_vec_spec(block_rows),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
        interpret=True,
    )(_as2d(p), _as2d(u), scalars)
    return out.reshape(chunk)
