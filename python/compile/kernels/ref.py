"""Pure-jnp reference oracles for the Pallas optimizer kernels.

These are the ground truth for pytest/hypothesis: every Pallas kernel in
`adama.py` must match its oracle to float32 tolerance for arbitrary shapes
and values. They also document the exact update math of the paper
(Algorithm 1/2 and Eq. 5-8).
"""
from __future__ import annotations

import jax.numpy as jnp

# Hyper-parameters baked into the AOT artifacts (see aot.py / manifest.json).
BETA1 = 0.9
BETA2 = 0.999
EPS = 1e-8


def adama_accumulate(m, v, g, gscale, *, beta1=BETA1, beta2=BETA2):
    """AdamA inner-loop accumulation (Alg. 2, lines inside the layer loop).

    ``g`` is the raw micro-batch gradient; ``gscale`` (scalar, typically 1/N
    or 1/(N*M)) applies the paper's g_{t,i} = (1/N) grad scaling.  Returns
    (m', v') with m' = m + (1-b1)*s*g and v' = v + (1-b2)*(s*g)^2.
    """
    sg = g * gscale
    return m + (1.0 - beta1) * sg, v + (1.0 - beta2) * sg * sg


def adama_decay(m, v, mscale, vscale):
    """Mini-batch-start decay (Alg. 2 line 3).

    Single device: mscale = beta1, vscale = beta2.  Distributed DP
    (Eq. 6): vscale = M * beta2 so that the post-all-reduce division by
    M^2 restores beta2 * v_{t-1}.
    """
    return m * mscale, v * vscale


def adama_decay_acc(m, v, g, gscale, mscale, vscale, *, beta1=BETA1,
                    beta2=BETA2):
    """Fused mini-batch-start decay + first micro-batch accumulation."""
    sg = g * gscale
    return (m * mscale + (1.0 - beta1) * sg,
            v * vscale + (1.0 - beta2) * sg * sg)


def adam_update(p, m, v, lr, bc1, bc2, *, eps=EPS):
    """Bias-corrected parameter step shared by Adam and AdamA.

    bc1 = 1 - beta1^t and bc2 = 1 - beta2^t are computed host-side (they
    are scalars); the kernel applies
        p' = p - lr * (m/bc1) / (sqrt(v/bc2) + eps).
    """
    mhat = m / bc1
    vhat = v / bc2
    return p - lr * mhat / (jnp.sqrt(vhat) + eps)


def adam_full_step(p, m, v, g, lr, bc1, bc2, *, beta1=BETA1, beta2=BETA2, eps=EPS):
    """Baseline fused Adam step from a fully-accumulated gradient.

    Standard Adam (blue text in Alg. 1): m' = b1*m + (1-b1)*g,
    v' = b2*v + (1-b2)*g^2, then the bias-corrected update.
    """
    m2 = beta1 * m + (1.0 - beta1) * g
    v2 = beta2 * v + (1.0 - beta2) * g * g
    p2 = p - lr * (m2 / bc1) / (jnp.sqrt(v2 / bc2) + eps)
    return p2, m2, v2


def grad_accumulate(acc, g, gscale):
    """Gradient-accumulation baseline: acc' = acc + gscale * g."""
    return acc + gscale * g


def adama_acc_update(p, m, v, g, gscale, lr, bc1, bc2,
                     *, beta1=BETA1, beta2=BETA2, eps=EPS):
    """Fused last-micro-batch op: accumulate g into (m, v) then step p.

    Used by the perf-optimized hot path to avoid one extra HBM round-trip
    on the final micro-batch of a mini-batch.
    """
    m2, v2 = adama_accumulate(m, v, g, gscale, beta1=beta1, beta2=beta2)
    p2 = adam_update(p, m2, v2, lr, bc1, bc2, eps=eps)
    return p2, m2, v2


# ---------------------------------------------------------------------------
# §5 extensions: the accumulation trick for other momentum-based optimizers.
# ---------------------------------------------------------------------------

def adamw_update(p, m, v, lr, bc1, bc2, wd, *, eps=EPS):
    """AdamW (decoupled weight decay) parameter step."""
    mhat = m / bc1
    vhat = v / bc2
    return p - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * p)


def sgdm_decay_acc(u, g, gscale, mu):
    """Momentum-SGD accumulation, first micro-batch (fused decay)."""
    return u * mu + g * gscale


def sgdm_acc(u, g, gscale):
    return u + g * gscale


def sgdm_update(p, u, lr, wd):
    return p - lr * (u + wd * p)
