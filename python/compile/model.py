"""Layer-2 JAX model: per-layer transformer LM + MLP classifier.

The model is decomposed into per-layer forward/backward functions so the
rust coordinator (L3) can drive a layer-by-layer backward sweep and release
every gradient buffer immediately after it is integrated into the optimizer
states — the execution pattern AdamA requires (paper §3.3, "backward hook").

Artifacts lowered from this module (see aot.py):

  embed_fwd   (tokens i32[B,S], E f32[V,H], P f32[S,H])        -> x
  embed_bwd   (tokens, dx)                                     -> (dE, dP)
  block_fwd   (x, *12 block params)                            -> y
  block_bwd   (x, dy, *12 block params)                        -> (dx, *12 dp)
  head_loss   (x, W f32[H,V], labels i32[B,S])                 -> (loss, dx, dW)
  head_eval   (x, W, labels)                                   -> (loss, ncorrect)
  mlp_train   (x f32[B,D], labels i32[B], W1, b1, W2, b2)      -> (loss, *4 dp)
  mlp_eval    (x, labels, W1, b1, W2, b2)                      -> (loss, ncorrect)

``block_bwd`` recomputes its forward internally (per-layer
rematerialisation): L3 only stashes the *input* activation of each layer per
micro-batch, so the activation footprint still scales with micro-batch size
(the paper's 1/N claim) while keeping the artifact set small.  DESIGN.md
§Substitutions documents this choice.

Losses are mean token cross-entropy over the micro-batch; the paper's 1/N
scaling of g_{t,i} is applied by the optimizer kernels' ``gscale`` input.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Transformer LM hyper-parameters baked into one artifact set."""

    name: str
    vocab: int
    hidden: int
    layers: int
    heads: int
    seq: int
    microbatch: int
    ffn_mult: int = 4

    @property
    def ffn(self) -> int:
        return self.hidden * self.ffn_mult

    def param_shapes(self):
        """Ordered (name, shape) list of every trainable tensor.

        Mirrored exactly by rust/src/model/spec.rs — keep in sync.
        """
        h, f, v, s = self.hidden, self.ffn, self.vocab, self.seq
        shapes = [("embed.E", (v, h)), ("embed.P", (s, h))]
        for i in range(self.layers):
            p = f"block{i}."
            shapes += [
                (p + "ln1.g", (h,)), (p + "ln1.b", (h,)),
                (p + "attn.wqkv", (h, 3 * h)), (p + "attn.bqkv", (3 * h,)),
                (p + "attn.wo", (h, h)), (p + "attn.bo", (h,)),
                (p + "ln2.g", (h,)), (p + "ln2.b", (h,)),
                (p + "mlp.w1", (h, f)), (p + "mlp.b1", (f,)),
                (p + "mlp.w2", (f, h)), (p + "mlp.b2", (h,)),
            ]
        shapes.append(("head.W", (h, v)))
        return shapes

    @property
    def n_params(self) -> int:
        return sum(int(jnp.prod(jnp.array(s))) for _, s in self.param_shapes())


# Named presets. `tiny` drives tests, `small` the end-to-end example.
CONFIGS = {
    "tiny": ModelConfig("tiny", vocab=256, hidden=64, layers=2, heads=2,
                        seq=32, microbatch=4),
    "small": ModelConfig("small", vocab=2048, hidden=256, layers=4, heads=4,
                         seq=64, microbatch=8),
    "base": ModelConfig("base", vocab=8192, hidden=512, layers=8, heads=8,
                        seq=128, microbatch=8),
}

# Order of the 12 per-block parameter tensors in block_fwd/block_bwd args.
BLOCK_PARAM_NAMES = [
    "ln1.g", "ln1.b", "attn.wqkv", "attn.bqkv", "attn.wo", "attn.bo",
    "ln2.g", "ln2.b", "mlp.w1", "mlp.b1", "mlp.w2", "mlp.b2",
]


# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------

def layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def causal_attention(x, wqkv, bqkv, wo, bo, heads):
    b, s, h = x.shape
    dh = h // heads
    qkv = x @ wqkv + bqkv                       # [B,S,3H]
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def heads_first(t):
        return t.reshape(b, s, heads, dh).transpose(0, 2, 1, 3)

    q, k, v = heads_first(q), heads_first(k), heads_first(v)
    scores = (q @ k.transpose(0, 1, 3, 2)) / jnp.sqrt(float(dh))
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = (probs @ v).transpose(0, 2, 1, 3).reshape(b, s, h)
    return out @ wo + bo


def block_apply(x, params, heads):
    """Pre-LN transformer block: x + attn(ln1(x)) ; + mlp(ln2(.))."""
    (ln1g, ln1b, wqkv, bqkv, wo, bo, ln2g, ln2b, w1, b1, w2, b2) = params
    a = causal_attention(layer_norm(x, ln1g, ln1b), wqkv, bqkv, wo, bo, heads)
    x = x + a
    m = layer_norm(x, ln2g, ln2b) @ w1 + b1
    m = jax.nn.gelu(m) @ w2 + b2
    return x + m


# ---------------------------------------------------------------------------
# artifact entry points
# ---------------------------------------------------------------------------

def embed_fwd(tokens, E, P):
    return E[tokens] + P[None, :, :]


def make_embed_bwd(cfg: ModelConfig):
    """VJP of embed_fwd w.r.t. (E, P): scatter-add + batch-sum."""

    def f(tokens, dx):
        dE = jnp.zeros((cfg.vocab, cfg.hidden), jnp.float32)
        dE = dE.at[tokens].add(dx)
        dP = jnp.sum(dx, axis=0)
        return dE, dP

    return f


def make_block_fwd(cfg: ModelConfig):
    def f(x, *params):
        return block_apply(x, params, cfg.heads)

    return f


def make_block_bwd(cfg: ModelConfig):
    fwd = make_block_fwd(cfg)

    def f(x, dy, *params):
        # Recompute forward (per-layer remat) and pull back dy.
        _, vjp = jax.vjp(fwd, x, *params)
        grads = vjp(dy)
        return grads  # (dx, *12 dparams)

    return f


def _token_xent(logits, labels):
    """Mean cross-entropy over all tokens; returns (loss, ncorrect)."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    pred = jnp.argmax(logits, axis=-1)
    return jnp.mean(nll), jnp.sum((pred == labels).astype(jnp.int32))


def make_head_loss(cfg: ModelConfig):
    def f(x, W, labels):
        def loss_fn(x, W):
            return _token_xent(x @ W, labels)[0]

        loss, vjp = jax.vjp(loss_fn, x, W)
        dx, dW = vjp(jnp.float32(1.0))
        return loss, dx, dW

    return f


def make_head_eval(cfg: ModelConfig):
    def f(x, W, labels):
        loss, ncorrect = _token_xent(x @ W, labels)
        return loss, ncorrect

    return f


# Full-model reference (used by python tests only, not lowered): composes
# the per-layer artifacts exactly as the rust coordinator does.
def lm_forward(cfg: ModelConfig, params: dict, tokens):
    x = embed_fwd(tokens, params["embed.E"], params["embed.P"])
    for i in range(cfg.layers):
        blk = [params[f"block{i}.{n}"] for n in BLOCK_PARAM_NAMES]
        x = block_apply(x, blk, cfg.heads)
    return x @ params["head.W"]


def lm_loss(cfg: ModelConfig, params: dict, tokens, labels):
    return _token_xent(lm_forward(cfg, params, tokens), labels)[0]


def init_params(cfg: ModelConfig, key) -> dict:
    """Scaled-normal init. The rust side has its own (identical) init; this
    one backs the python-level oracle tests."""
    params = {}
    for name, shape in cfg.param_shapes():
        key, sub = jax.random.split(key)
        if name.endswith((".b", ".g", ".bqkv", ".bo", ".b1", ".b2")):
            params[name] = (jnp.ones(shape, jnp.float32)
                            if name.endswith(".g")
                            else jnp.zeros(shape, jnp.float32))
        else:
            fan_in = shape[0] if len(shape) > 1 else 1
            std = 0.02 if name.startswith("embed") else fan_in ** -0.5
            params[name] = std * jax.random.normal(sub, shape, jnp.float32)
    return params


# ---------------------------------------------------------------------------
# MLP classifier (Fig-3 vision-parity substitute)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MlpConfig:
    name: str
    features: int
    hidden: int
    classes: int
    microbatch: int


MLP_CONFIGS = {
    "tiny": MlpConfig("tiny", features=16, hidden=32, classes=4, microbatch=8),
    "small": MlpConfig("small", features=32, hidden=128, classes=10,
                       microbatch=16),
}


def mlp_apply(x, W1, b1, W2, b2):
    h = jax.nn.relu(x @ W1 + b1)
    return h @ W2 + b2


def make_mlp_train(cfg: MlpConfig):
    def f(x, labels, W1, b1, W2, b2):
        def loss_fn(W1, b1, W2, b2):
            logits = mlp_apply(x, W1, b1, W2, b2)
            logp = jax.nn.log_softmax(logits, axis=-1)
            return -jnp.mean(jnp.take_along_axis(logp, labels[:, None],
                                                 axis=-1))

        loss, vjp = jax.vjp(loss_fn, W1, b1, W2, b2)
        grads = vjp(jnp.float32(1.0))
        return (loss,) + grads

    return f


def make_mlp_eval(cfg: MlpConfig):
    def f(x, labels, W1, b1, W2, b2):
        logits = mlp_apply(x, W1, b1, W2, b2)
        logp = jax.nn.log_softmax(logits, axis=-1)
        loss = -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))
        ncorrect = jnp.sum((jnp.argmax(logits, axis=-1) == labels)
                           .astype(jnp.int32))
        return loss, ncorrect

    return f
