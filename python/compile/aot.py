"""AOT compile path: lower every L2/L1 artifact to HLO *text* + manifest.

HLO text (NOT ``lowered.compile().serialize()`` / serialized protos) is the
interchange format: jax >= 0.5 emits HloModuleProtos with 64-bit instruction
ids which the xla crate's xla_extension 0.5.1 rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage (from python/):  python -m compile.aot --out ../artifacts
Runs once at build time (`make artifacts`); rust never imports python.
"""
from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import adama, ref

CHUNK_SIZES = [16384, 65536, 1048576]

_DTYPE_NAMES = {jnp.float32.dtype: "f32", jnp.int32.dtype: "s32"}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec_of(x):
    return {"shape": list(x.shape), "dtype": _DTYPE_NAMES[x.dtype]}


def lower_artifact(fn, arg_specs, out_dir, rel_path):
    """Lower fn at arg_specs, write HLO text, return manifest entry."""
    # keep_unused: backward artifacts take parameters whose *values* are
    # dead in the gradient math (e.g. additive biases); the rust caller
    # always supplies the full positional signature.
    lowered = jax.jit(fn, keep_unused=True).lower(*arg_specs)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, rel_path)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(text)
    outs = jax.eval_shape(fn, *arg_specs)
    if not isinstance(outs, (tuple, list)):
        outs = (outs,)
    return {
        "file": rel_path,
        "inputs": [_spec_of(s) for s in arg_specs],
        "outputs": [_spec_of(o) for o in outs],
        "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
    }


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def s32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def lower_model_config(cfg: model.ModelConfig, out_dir):
    """All per-config transformer artifacts."""
    b, s, h, v = cfg.microbatch, cfg.seq, cfg.hidden, cfg.vocab
    blk = [f32(*shape) for name, shape in cfg.param_shapes()
           if name.startswith("block0.")]
    d = cfg.name
    arts = {}
    arts["embed_fwd"] = lower_artifact(
        model.embed_fwd, [s32(b, s), f32(v, h), f32(s, h)],
        out_dir, f"{d}/embed_fwd.hlo.txt")
    arts["embed_bwd"] = lower_artifact(
        model.make_embed_bwd(cfg), [s32(b, s), f32(b, s, h)],
        out_dir, f"{d}/embed_bwd.hlo.txt")
    arts["block_fwd"] = lower_artifact(
        model.make_block_fwd(cfg), [f32(b, s, h)] + blk,
        out_dir, f"{d}/block_fwd.hlo.txt")
    arts["block_bwd"] = lower_artifact(
        model.make_block_bwd(cfg), [f32(b, s, h), f32(b, s, h)] + blk,
        out_dir, f"{d}/block_bwd.hlo.txt")
    arts["head_loss"] = lower_artifact(
        model.make_head_loss(cfg), [f32(b, s, h), f32(h, v), s32(b, s)],
        out_dir, f"{d}/head_loss.hlo.txt")
    arts["head_eval"] = lower_artifact(
        model.make_head_eval(cfg), [f32(b, s, h), f32(h, v), s32(b, s)],
        out_dir, f"{d}/head_eval.hlo.txt")
    entry = {
        "model": {
            "vocab": cfg.vocab, "hidden": cfg.hidden, "layers": cfg.layers,
            "heads": cfg.heads, "seq": cfg.seq, "microbatch": cfg.microbatch,
            "ffn": cfg.ffn,
        },
        "param_shapes": [[n, list(sh)] for n, sh in cfg.param_shapes()],
        "artifacts": arts,
    }
    return entry


def lower_mlp_config(cfg: model.MlpConfig, out_dir):
    b, dft, hid, cls = cfg.microbatch, cfg.features, cfg.hidden, cfg.classes
    params = [f32(dft, hid), f32(hid), f32(hid, cls), f32(cls)]
    d = f"mlp_{cfg.name}"
    arts = {}
    arts["mlp_train"] = lower_artifact(
        model.make_mlp_train(cfg), [f32(b, dft), s32(b)] + params,
        out_dir, f"{d}/mlp_train.hlo.txt")
    arts["mlp_eval"] = lower_artifact(
        model.make_mlp_eval(cfg), [f32(b, dft), s32(b)] + params,
        out_dir, f"{d}/mlp_eval.hlo.txt")
    return {
        "model": {"features": dft, "hidden": hid, "classes": cls,
                  "microbatch": b},
        "artifacts": arts,
    }


def lower_optimizer_kernels(out_dir):
    """Chunked Pallas optimizer kernels, one artifact set per chunk size."""
    arts = {}
    for c in CHUNK_SIZES:
        arts[f"adama_acc_{c}"] = lower_artifact(
            adama.adama_accumulate, [f32(c), f32(c), f32(c), f32(1)],
            out_dir, f"common/adama_acc_{c}.hlo.txt")
        arts[f"adama_decay_acc_{c}"] = lower_artifact(
            adama.adama_decay_acc, [f32(c), f32(c), f32(c), f32(3)],
            out_dir, f"common/adama_decay_acc_{c}.hlo.txt")
        arts[f"adama_decay_{c}"] = lower_artifact(
            adama.adama_decay, [f32(c), f32(c), f32(1), f32(1)],
            out_dir, f"common/adama_decay_{c}.hlo.txt")
        arts[f"adam_update_{c}"] = lower_artifact(
            adama.adam_update, [f32(c), f32(c), f32(c), f32(3)],
            out_dir, f"common/adam_update_{c}.hlo.txt")
        arts[f"adam_full_{c}"] = lower_artifact(
            adama.adam_full_step, [f32(c), f32(c), f32(c), f32(c), f32(3)],
            out_dir, f"common/adam_full_{c}.hlo.txt")
        arts[f"grad_acc_{c}"] = lower_artifact(
            adama.grad_accumulate, [f32(c), f32(c), f32(1)],
            out_dir, f"common/grad_acc_{c}.hlo.txt")
        arts[f"adama_acc_update_{c}"] = lower_artifact(
            adama.adama_acc_update,
            [f32(c), f32(c), f32(c), f32(c), f32(1), f32(3)],
            out_dir, f"common/adama_acc_update_{c}.hlo.txt")
        # §5 extensions: AdamW-A and momentum-SGD accumulation
        arts[f"adamw_update_{c}"] = lower_artifact(
            adama.adamw_update, [f32(c), f32(c), f32(c), f32(4)],
            out_dir, f"common/adamw_update_{c}.hlo.txt")
        arts[f"sgdm_decay_acc_{c}"] = lower_artifact(
            adama.sgdm_decay_acc, [f32(c), f32(c), f32(2)],
            out_dir, f"common/sgdm_decay_acc_{c}.hlo.txt")
        arts[f"sgdm_acc_{c}"] = lower_artifact(
            adama.sgdm_acc, [f32(c), f32(c), f32(1)],
            out_dir, f"common/sgdm_acc_{c}.hlo.txt")
        arts[f"sgdm_update_{c}"] = lower_artifact(
            adama.sgdm_update, [f32(c), f32(c), f32(2)],
            out_dir, f"common/sgdm_update_{c}.hlo.txt")
    return arts


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--configs", default="tiny,small")
    ap.add_argument("--mlp-configs", default="tiny,small")
    args = ap.parse_args()
    out = args.out
    os.makedirs(out, exist_ok=True)

    manifest = {
        "hyper": {"beta1": ref.BETA1, "beta2": ref.BETA2, "eps": ref.EPS},
        "chunk_sizes": CHUNK_SIZES,
        "configs": {},
        "mlp_configs": {},
    }
    manifest["common"] = lower_optimizer_kernels(out)
    print(f"lowered {len(manifest['common'])} optimizer kernel artifacts")
    for name in args.configs.split(","):
        cfg = model.CONFIGS[name]
        manifest["configs"][name] = lower_model_config(cfg, out)
        print(f"lowered model config '{name}' "
              f"({cfg.n_params/1e6:.2f}M params)")
    for name in args.mlp_configs.split(","):
        cfg = model.MLP_CONFIGS[name]
        manifest["mlp_configs"][name] = lower_mlp_config(cfg, out)
        print(f"lowered mlp config '{name}'")

    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {out}/manifest.json")


if __name__ == "__main__":
    main()
