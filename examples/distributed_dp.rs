//! Distributed data-parallel demo: the paper's optimizer-state all-reduce
//! (Eq. 5-8) vs gradient all-reduce vs the naive per-micro-batch scheme,
//! with measured communication volumes and per-rank memory peaks.
//!
//!     cargo run --release --example distributed_dp -- --workers 2 --steps 5
//!
//! `--engine fabric|channel|serial` picks the execution engine (default:
//! the concurrent fabric; all engines are bit-identical). `--workers`
//! defaults to `ADAMA_RANKS` when set; `ADAMA_FABRIC=ring|tree` picks the
//! reduction topology.

use adama::collective::{
    run_data_parallel, run_zero1, CollectiveEngine, DpSpec, SyncStrategy, Zero1Spec,
};
use adama::config::{OptimBackend, OptimizerKind, TrainConfig};
use adama::runtime::ArtifactLibrary;
use adama::util::cliargs::Args;
use adama::util::stats::fmt_bytes;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env();
    // ADAMA_RANKS accepts an integer or a comma list (the sweep spelling
    // the distributed tests use); the example runs the first entry
    let default_workers = match std::env::var("ADAMA_RANKS") {
        Ok(s) if !s.trim().is_empty() => {
            let first = s.split(',').next().unwrap_or("").trim();
            first.parse::<usize>().map_err(|_| {
                anyhow::anyhow!(
                    "invalid ADAMA_RANKS '{s}': expected a positive integer or comma list"
                )
            })?
        }
        _ => 2,
    };
    let workers = args.parse_or("workers", default_workers)?;
    let steps = args.parse_or("steps", 5u64)?;
    let n = args.parse_or("accum-steps", 4usize)?;
    let engine = match args.get("engine").unwrap_or("fabric") {
        "serial" => CollectiveEngine::Serial,
        "channel" => CollectiveEngine::Channel,
        "fabric" => CollectiveEngine::Fabric,
        other => anyhow::bail!("unknown --engine '{other}' (expected serial|channel|fabric)"),
    };
    let lib = ArtifactLibrary::open_default()?;

    let cfg = |opt| TrainConfig {
        model: "tiny".into(),
        optimizer: opt,
        backend: OptimBackend::Kernel,
        accum_steps: n,
        workers,
        ..TrainConfig::default()
    };

    println!("=== {workers} workers, N={n}, {steps} steps, engine={} ===\n", engine.name());
    println!(
        "{:<24} {:>10} {:>10} {:>14} {:>10}",
        "strategy", "loss[0]", "loss[-1]", "comm/step", "wall (s)"
    );
    let mut state_world = None;
    for (sync, opt) in [
        (SyncStrategy::OptimizerStates, OptimizerKind::AdamA),
        (SyncStrategy::Gradients, OptimizerKind::AdamGA),
        (SyncStrategy::GradPerMicrobatch, OptimizerKind::AdamA),
    ] {
        let r = run_data_parallel(
            lib.clone(),
            DpSpec::new(cfg(opt), sync, steps, 7).with_engine(engine),
        )?;
        println!(
            "{:<24} {:>10.4} {:>10.4} {:>14} {:>10.2}",
            sync.name(),
            r.losses[0],
            r.losses.last().unwrap(),
            fmt_bytes((r.comm_bytes / steps) as usize),
            r.elapsed_s,
        );
        if sync == SyncStrategy::OptimizerStates {
            state_world = Some(r.world_memory());
        }
    }

    if let Some(world) = state_world {
        println!("\n--- per-rank memory (state-allreduce run) ---");
        for (rank, snap) in world.ranks.iter().enumerate() {
            println!(
                "rank {rank}: weights {} grads {} states {} activations {} total {}",
                fmt_bytes(snap.tracker.peak_weights),
                fmt_bytes(snap.tracker.peak_gradients),
                fmt_bytes(snap.tracker.peak_optimizer),
                fmt_bytes(snap.tracker.peak_activations),
                fmt_bytes(snap.tracker.peak_total),
            );
        }
        if let Some(mx) = world.max_per_rank() {
            println!(
                "max/rank total {}   cluster total {}",
                fmt_bytes(mx.tracker.peak_total),
                fmt_bytes(world.total_peak_bytes() as usize),
            );
        }
    }

    if workers >= 2 {
        println!("\n--- ZeRO-S1 (optimizer states partitioned across workers) ---");
        for opt in [OptimizerKind::AdamA, OptimizerKind::AdamGA] {
            let r = run_zero1(
                lib.clone(),
                Zero1Spec::new(cfg(opt), steps, 7).with_engine(engine),
            )?;
            println!(
                "ZeRO-S1+{:<8} loss {:.4} -> {:.4}   comm/step {}   grads peak {}   optstate {}",
                opt.name(),
                r.losses[0],
                r.losses.last().unwrap(),
                fmt_bytes((r.comm_bytes / steps) as usize),
                fmt_bytes(r.memory.peak_gradients),
                fmt_bytes(r.memory.peak_optimizer),
            );
        }
    }
    println!("\nall ranks verified bit-identical after every run (asserted in the runner)");
    Ok(())
}
