//! Distributed data-parallel demo: the paper's optimizer-state all-reduce
//! (Eq. 5-8) vs gradient all-reduce vs the naive per-micro-batch scheme,
//! with measured communication volumes.
//!
//!     cargo run --release --example distributed_dp -- --workers 2 --steps 5

use adama::collective::{run_data_parallel, run_zero1, DpSpec, SyncStrategy, Zero1Spec};
use adama::config::{OptimBackend, OptimizerKind, TrainConfig};
use adama::runtime::ArtifactLibrary;
use adama::util::cliargs::Args;
use adama::util::stats::fmt_bytes;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env();
    let workers = args.parse_or("workers", 2usize)?;
    let steps = args.parse_or("steps", 5u64)?;
    let n = args.parse_or("accum-steps", 4usize)?;
    let lib = ArtifactLibrary::open_default()?;

    let cfg = |opt| TrainConfig {
        model: "tiny".into(),
        optimizer: opt,
        backend: OptimBackend::Kernel,
        accum_steps: n,
        workers,
        ..TrainConfig::default()
    };

    println!("=== {workers} workers, N={n}, {steps} steps ===\n");
    println!(
        "{:<24} {:>10} {:>10} {:>14} {:>10}",
        "strategy", "loss[0]", "loss[-1]", "comm/step", "wall (s)"
    );
    for (sync, opt) in [
        (SyncStrategy::OptimizerStates, OptimizerKind::AdamA),
        (SyncStrategy::Gradients, OptimizerKind::AdamGA),
        (SyncStrategy::GradPerMicrobatch, OptimizerKind::AdamA),
    ] {
        let r = run_data_parallel(
            lib.clone(),
            DpSpec { cfg: cfg(opt), sync, steps, data_seed: 7 },
        )?;
        println!(
            "{:<24} {:>10.4} {:>10.4} {:>14} {:>10.2}",
            sync.name(),
            r.losses[0],
            r.losses.last().unwrap(),
            fmt_bytes((r.comm_bytes / steps) as usize),
            r.elapsed_s,
        );
    }

    println!("\n--- ZeRO-S1 (optimizer states partitioned across workers) ---");
    for opt in [OptimizerKind::AdamA, OptimizerKind::AdamGA] {
        let r = run_zero1(lib.clone(), Zero1Spec { cfg: cfg(opt), steps, data_seed: 7 })?;
        println!(
            "ZeRO-S1+{:<8} loss {:.4} -> {:.4}   comm/step {}   grads peak {}   optstate {}",
            opt.name(),
            r.losses[0],
            r.losses.last().unwrap(),
            fmt_bytes((r.comm_bytes / steps) as usize),
            fmt_bytes(r.memory.peak_gradients),
            fmt_bytes(r.memory.peak_optimizer),
        );
    }
    println!("\nall ranks verified bit-identical after every run (asserted in the runner)");
    Ok(())
}
