//! Memory sweep: measured per-category peaks across optimizers and
//! accumulation depths at `tiny`/`small` scale, next to the analytic
//! model's projection of the same run — then the host executor's
//! stash-vs-remat activation budget sweep (`ADAMA_ACT_BUDGET`), and
//! finally the paper-scale projection for BERT-Large and BERT-4B.
//!
//!     cargo run --release --example memory_sweep -- --model tiny

use adama::config::{OptimBackend, OptimizerKind, TrainConfig};
use adama::data::MarkovCorpus;
use adama::memmodel::{peak_memory, DtypePolicy, HostBlockDims, PaperModel, Scenario, Strategy};
use adama::runtime::{ArtifactLibrary, Library, MemoryPlan};
use adama::util::cliargs::Args;
use adama::util::stats::fmt_bytes;
use adama::{Category, Trainer};

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env();
    let model = args.str_or("model", "tiny");
    let lib = ArtifactLibrary::open_default()?;

    println!("=== measured ({model} scale, real training runs) ===");
    println!(
        "{:<8} {:>3} {:>12} {:>12} {:>12} {:>12}",
        "optim", "N", "weights", "grads", "optstate", "acts"
    );
    for opt in [OptimizerKind::AdamGA, OptimizerKind::AdamA] {
        for n in [2usize, 8] {
            let cfg = TrainConfig {
                model: model.clone(),
                optimizer: opt,
                backend: OptimBackend::Kernel,
                accum_steps: n,
                ..TrainConfig::default()
            };
            let mut t = Trainer::new(lib.clone(), cfg)?;
            let h = t.spec().hyper.clone();
            let mut c = MarkovCorpus::new(h.vocab, 7, 1);
            for _ in 0..2 {
                t.train_step(&c.minibatch(n, h.microbatch, h.seq))?;
            }
            let tr = t.tracker();
            println!(
                "{:<8} {n:>3} {:>12} {:>12} {:>12} {:>12}",
                opt.name(),
                fmt_bytes(tr.peak(Category::Weights)),
                fmt_bytes(tr.peak(Category::Gradients)),
                fmt_bytes(tr.peak(Category::OptimizerStates)),
                fmt_bytes(tr.peak(Category::Activations)),
            );
        }
    }

    println!("\n=== activation budget sweep ({model} scale, ADAMA_ACT_BUDGET) ===");
    let hyper = lib.manifest().model_config(&model)?.model.clone();
    let dims = HostBlockDims::from_model(&hyper);
    let blocks = hyper.layers as u64;
    let entry = dims.stash_entry_bytes();
    println!("per-block stash entry: {} ({} blocks)", fmt_bytes(entry as usize), blocks);
    println!(
        "{:<10} {:>12} {:>12} {:>12} {:>6} {:>7} {:>10}",
        "budget", "stash peak", "predicted", "ws peak", "hits", "remats", "steps/s"
    );
    for (name, plan) in [
        ("0", MemoryPlan::remat()),
        ("half", MemoryPlan::bytes(entry * blocks / 2)),
        ("unlimited", MemoryPlan::unlimited()),
    ] {
        let plib = Library::host_with_plan(lib.executor().threads(), plan);
        let cfg = TrainConfig {
            model: model.clone(),
            optimizer: OptimizerKind::AdamA,
            backend: OptimBackend::Kernel,
            accum_steps: 2,
            ..TrainConfig::default()
        };
        let mut t = Trainer::new(plib.clone(), cfg)?;
        let h = t.spec().hyper.clone();
        let mut c = MarkovCorpus::new(h.vocab, 7, 1);
        let t0 = std::time::Instant::now();
        let steps = 4;
        for _ in 0..steps {
            t.train_step(&c.minibatch(2, h.microbatch, h.seq))?;
        }
        let mem = plib.executor().memory().expect("host executor memory stats");
        println!(
            "{:<10} {:>12} {:>12} {:>12} {:>6} {:>7} {:>10.2}",
            name,
            fmt_bytes(mem.stash_peak_bytes as usize),
            fmt_bytes(dims.predicted_stash_peak_bytes(plan, blocks) as usize),
            fmt_bytes(mem.workspace_peak_bytes as usize),
            mem.stash_hits,
            mem.remats,
            steps as f64 / t0.elapsed().as_secs_f64(),
        );
    }
    println!("(stash skips the block-forward recompute inside block_bwd; remat re-runs it)");

    println!("\n=== analytic projection (paper scale, fp32 policy) ===");
    println!(
        "{:<12} {:<16} {:>10} {:>10} {:>10} {:>10} {:>11}",
        "model", "strategy", "weights", "grads", "optstate", "acts", "TOTAL (GB)"
    );
    for m in [PaperModel::bert_large(), PaperModel::bert_4b()] {
        for strategy in [Strategy::GradAccum, Strategy::AdamA, Strategy::Zero1AdamA] {
            let b = peak_memory(&Scenario {
                model: m.clone(),
                dtype: DtypePolicy::paper_fp32(),
                strategy,
                optimizer: OptimizerKind::AdamGA,
                minibatch_per_gpu: 32,
                accum_steps: 8,
                gpus: 8,
            });
            let gb = |x: u64| x as f64 / 1e9;
            println!(
                "{:<12} {:<16} {:>10.2} {:>10.2} {:>10.2} {:>10.2} {:>11.2}",
                m.name,
                strategy.name(),
                gb(b.weights),
                gb(b.gradients),
                gb(b.optimizer_states),
                gb(b.activations),
                gb(b.total()),
            );
        }
    }
    Ok(())
}
