//! Memory sweep: measured per-category peaks across optimizers and
//! accumulation depths at `tiny`/`small` scale, next to the analytic
//! model's projection of the same run — then the paper-scale projection
//! for BERT-Large and BERT-4B.
//!
//!     cargo run --release --example memory_sweep -- --model tiny

use adama::config::{OptimBackend, OptimizerKind, TrainConfig};
use adama::data::MarkovCorpus;
use adama::memmodel::{peak_memory, DtypePolicy, PaperModel, Scenario, Strategy};
use adama::runtime::ArtifactLibrary;
use adama::util::cliargs::Args;
use adama::util::stats::fmt_bytes;
use adama::{Category, Trainer};

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env();
    let model = args.str_or("model", "tiny");
    let lib = ArtifactLibrary::open_default()?;

    println!("=== measured ({model} scale, real training runs) ===");
    println!(
        "{:<8} {:>3} {:>12} {:>12} {:>12} {:>12}",
        "optim", "N", "weights", "grads", "optstate", "acts"
    );
    for opt in [OptimizerKind::AdamGA, OptimizerKind::AdamA] {
        for n in [2usize, 8] {
            let cfg = TrainConfig {
                model: model.clone(),
                optimizer: opt,
                backend: OptimBackend::Kernel,
                accum_steps: n,
                ..TrainConfig::default()
            };
            let mut t = Trainer::new(lib.clone(), cfg)?;
            let h = t.spec().hyper.clone();
            let mut c = MarkovCorpus::new(h.vocab, 7, 1);
            for _ in 0..2 {
                t.train_step(&c.minibatch(n, h.microbatch, h.seq))?;
            }
            let tr = t.tracker();
            println!(
                "{:<8} {n:>3} {:>12} {:>12} {:>12} {:>12}",
                opt.name(),
                fmt_bytes(tr.peak(Category::Weights)),
                fmt_bytes(tr.peak(Category::Gradients)),
                fmt_bytes(tr.peak(Category::OptimizerStates)),
                fmt_bytes(tr.peak(Category::Activations)),
            );
        }
    }

    println!("\n=== analytic projection (paper scale, fp32 policy) ===");
    println!(
        "{:<12} {:<16} {:>10} {:>10} {:>10} {:>10} {:>11}",
        "model", "strategy", "weights", "grads", "optstate", "acts", "TOTAL (GB)"
    );
    for m in [PaperModel::bert_large(), PaperModel::bert_4b()] {
        for strategy in [Strategy::GradAccum, Strategy::AdamA, Strategy::Zero1AdamA] {
            let b = peak_memory(&Scenario {
                model: m.clone(),
                dtype: DtypePolicy::paper_fp32(),
                strategy,
                optimizer: OptimizerKind::AdamGA,
                minibatch_per_gpu: 32,
                accum_steps: 8,
                gpus: 8,
            });
            let gb = |x: u64| x as f64 / 1e9;
            println!(
                "{:<12} {:<16} {:>10.2} {:>10.2} {:>10.2} {:>10.2} {:>11.2}",
                m.name,
                strategy.name(),
                gb(b.weights),
                gb(b.gradients),
                gb(b.optimizer_states),
                gb(b.activations),
                gb(b.total()),
            );
        }
    }
    Ok(())
}
