//! Serving demo: train a few steps, checkpoint, then serve the
//! checkpoint through the batched KV-cache inference engine.
//!
//! ```text
//! cargo run --release --example serve_demo
//! ADAMA_KV_BUDGET=16k cargo run --release --example serve_demo
//! ```
//!
//! Part 1 produces an `ADAMACK2` checkpoint with the trainer. Part 2
//! loads it into the forward-only engine and drives a deterministic
//! synthetic request stream through the continuous-batching scheduler,
//! printing throughput, latency percentiles, and the exact agreement
//! between measured KV bytes and the `memmodel` closed form. Run it
//! twice (with and without `ADAMA_KV_BUDGET`) to watch eviction trade
//! latency for memory without changing a single output token.

use adama::config::{OptimizerKind, TrainConfig};
use adama::data::MarkovCorpus;
use adama::memmodel::HostBlockDims;
use adama::runtime::Library;
use adama::serve::{kv_budget_from_env, InferenceEngine, Scheduler, SyntheticLoad};
use adama::util::stats::fmt_bytes;
use adama::Trainer;

fn main() -> anyhow::Result<()> {
    let lib = Library::open_default()?;
    println!(
        "execution backend: {} ({} pool thread(s))",
        lib.executor().platform(),
        lib.executor().threads()
    );

    // ---- part 1: train briefly and checkpoint ----
    let cfg = TrainConfig {
        model: "tiny".into(),
        optimizer: OptimizerKind::AdamA,
        accum_steps: 4,
        ..TrainConfig::default()
    };
    let mut trainer = Trainer::new(lib.clone(), cfg)?;
    let h = trainer.spec().hyper.clone();
    let mut corpus = MarkovCorpus::new(h.vocab, 7, 1);
    for _ in 0..5 {
        trainer.train_step(&corpus.minibatch(4, h.microbatch, h.seq))?;
    }
    let dir = std::env::temp_dir().join(format!("adama_serve_demo_{}", std::process::id()));
    std::fs::create_dir_all(&dir)?;
    let ckpt = dir.join("demo.ack2");
    trainer.save_state(&ckpt, &[])?;
    println!("checkpointed {} steps to {}", 5, ckpt.display());
    drop(trainer);

    // ---- part 2: serve the checkpoint ----
    let engine = InferenceEngine::from_checkpoint(lib.clone(), "tiny", &ckpt)?;
    let dims = HostBlockDims::from_model(engine.hyper());
    let layers = engine.hyper().layers as u64;
    let budget = kv_budget_from_env()?;
    match budget {
        Some(cap) => println!(
            "ADAMA_KV_BUDGET={} -> at most {} cached tokens across the batch",
            fmt_bytes(cap as usize),
            dims.kv_budget_tokens(layers, cap)
        ),
        None => println!("ADAMA_KV_BUDGET unset -> KV cache uncapped"),
    }

    let load = SyntheticLoad { requests: 8, prompt_len: 8, max_new: 8, arrive_every: 1, seed: 9 };
    let mut sched = Scheduler::with_budget(engine, /*max_batch=*/ 4, budget);
    let stats = load.run(&mut sched)?;

    println!(
        "\nserved {} requests / {} tokens in {} decode steps",
        stats.requests(),
        stats.tokens(),
        sched.steps()
    );
    println!(
        "throughput {:.0} tok/s   latency p50 {:.1} ms, p99 {:.1} ms",
        stats.tokens_per_sec(),
        1e3 * stats.p50(),
        1e3 * stats.p99()
    );
    println!(
        "KV accounting: one token pins {} across {} blocks; a full {}-token \
         context would pin {} — measured and modelled bytes agree exactly \
         (asserted in rust/tests/serve.rs)",
        fmt_bytes(sched.engine().kv_bytes_per_token() as usize),
        layers,
        dims.seq,
        fmt_bytes(dims.kv_cache_bytes(layers, dims.seq) as usize)
    );

    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}
