//! Convergence parity demo (the paper's central empirical claim): train
//! the same model on the same data with Adam+gradient-accumulation and
//! with AdamA, across several accumulation depths, and show the loss
//! trajectories coincide while the memory profiles don't.
//!
//!     cargo run --release --example convergence_parity -- --steps 30

use adama::config::{OptimizerKind, TrainConfig};
use adama::data::MarkovCorpus;
use adama::runtime::ArtifactLibrary;
use adama::util::cliargs::Args;
use adama::util::stats::fmt_bytes;
use adama::{Category, Trainer};

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env();
    let steps = args.parse_or("steps", 30u64)?;
    let lib = ArtifactLibrary::open_default()?;

    for n in [2usize, 4, 8] {
        println!("\n=== N = {n} micro-batches per mini-batch ===");
        let mk = |opt| {
            let cfg = TrainConfig {
                model: "tiny".into(),
                optimizer: opt,
                accum_steps: n,
                ..TrainConfig::default()
            };
            Trainer::new(lib.clone(), cfg)
        };
        let mut adam = mk(OptimizerKind::AdamGA)?;
        let mut adama = mk(OptimizerKind::AdamA)?;
        let h = adam.spec().hyper.clone();
        let mut c1 = MarkovCorpus::new(h.vocab, 7, 10 + n as u64);
        let mut c2 = MarkovCorpus::new(h.vocab, 7, 10 + n as u64);

        println!("{:>5} {:>12} {:>12} {:>8}", "step", "Adam", "AdamA", "|Δ|");
        let mut max_gap = 0.0f32;
        for s in 1..=steps {
            let a = adam.train_step(&c1.minibatch(n, h.microbatch, h.seq))?;
            let b = adama.train_step(&c2.minibatch(n, h.microbatch, h.seq))?;
            max_gap = max_gap.max((a.loss - b.loss).abs());
            if s % 5 == 0 || s == 1 {
                println!(
                    "{s:>5} {:>12.4} {:>12.4} {:>8.4}",
                    a.loss,
                    b.loss,
                    (a.loss - b.loss).abs()
                );
            }
        }
        println!("max loss gap over {steps} steps: {max_gap:.4}");
        println!(
            "gradient memory peak:  Adam+GA {}  vs  AdamA {}",
            fmt_bytes(adam.tracker().peak(Category::Gradients)),
            fmt_bytes(adama.tracker().peak(Category::Gradients)),
        );
    }
    Ok(())
}
