//! Quickstart: train on the pure-rust host executor — no artifacts, no
//! Python, no PJRT — and print loss curves plus the *measured* memory
//! breakdown from the tracker.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```
//!
//! Part 1 trains the MLP classifier (the paper's vision-parity model);
//! part 2 trains the tiny transformer LM through the same AdamA
//! release-per-layer protocol.

use adama::config::{LrSchedule, OptimizerKind, TrainConfig};
use adama::coordinator::MlpTrainer;
use adama::data::{BlobData, MarkovCorpus};
use adama::runtime::Library;
use adama::Trainer;

fn main() -> anyhow::Result<()> {
    // 1. open the default library: host executor on a clean machine,
    //    PJRT artifacts when built with `--features pjrt` + `make artifacts`
    let lib = Library::open_default()?;
    println!(
        "execution backend: {} ({} pool thread(s); set ADAMA_THREADS to override — \
         results are bit-identical at any thread count)",
        lib.executor().platform(),
        lib.executor().threads()
    );

    // ---- part 1: MLP classifier with AdamA ----
    let cfg = TrainConfig {
        model: "tiny".into(),
        optimizer: OptimizerKind::AdamA,
        accum_steps: 4,
        lr: LrSchedule::constant(5e-2),
        ..TrainConfig::default()
    };
    let mut mlp = MlpTrainer::new(lib.clone(), cfg)?;
    let h = mlp.hyper.clone();
    println!(
        "\nMLP '{}': {} features -> {} hidden -> {} classes, N=4 micro-batches",
        "tiny", h.features, h.hidden, h.classes
    );
    let mut blobs = BlobData::new(h.features, h.classes, 7, 1);
    for step in 1..=30u64 {
        let minibatch: Vec<_> = (0..4).map(|_| blobs.batch(h.microbatch)).collect();
        let loss = mlp.train_step(&minibatch)?;
        if step % 5 == 0 || step == 1 {
            println!("step {step:>3}  loss {loss:.4}");
        }
    }
    let eval: Vec<_> = (0..4).map(|_| blobs.batch(h.microbatch)).collect();
    let (loss, acc) = mlp.eval(&eval)?;
    println!("eval: loss {loss:.4}, accuracy {:.1}%", 100.0 * acc);
    println!("\n{}", mlp.tracker().report());

    // ---- part 2: tiny transformer LM, same optimizer protocol ----
    let cfg = TrainConfig {
        model: "tiny".into(),
        optimizer: OptimizerKind::AdamA,
        accum_steps: 4,
        ..TrainConfig::default()
    };
    let mut trainer = Trainer::new(lib, cfg)?;
    let th = trainer.spec().hyper.clone();
    println!(
        "\ntransformer '{}': {} params across {} layers (max layer {})",
        trainer.spec().config,
        trainer.spec().total_params(),
        trainer.spec().n_layers(),
        trainer.spec().max_layer_params(),
    );
    let mut corpus = MarkovCorpus::new(th.vocab, 7, 1);
    println!("corpus entropy floor: {:.3} nats", corpus.entropy());
    for step in 1..=10u64 {
        let minibatch = corpus.minibatch(4, th.microbatch, th.seq);
        let stats = trainer.train_step(&minibatch)?;
        if step % 5 == 0 || step == 1 {
            println!(
                "step {:>3}  loss {:.4}  lr {:.1e}  {:.0} tok/s",
                stats.step,
                stats.loss,
                stats.lr,
                stats.tokens_per_sec()
            );
        }
    }
    println!("\n{}", trainer.tracker().report());
    println!(
        "\nAdamA gradient peak = one layer ({} bytes), not the full model ({} bytes)",
        trainer.spec().max_layer_params() * 4,
        trainer.spec().total_params() * 4
    );
    Ok(())
}
