//! Quickstart: train the tiny transformer with AdamA for a handful of
//! steps and print the loss curve + the measured memory breakdown.
//!
//!     make artifacts && cargo run --release --example quickstart

use adama::config::{OptimizerKind, TrainConfig};
use adama::data::MarkovCorpus;
use adama::runtime::ArtifactLibrary;
use adama::Trainer;

fn main() -> anyhow::Result<()> {
    // 1. open the AOT artifacts (built once by `make artifacts`)
    let lib = ArtifactLibrary::open_default()?;
    println!("PJRT platform: {}", lib.engine().platform_name());

    // 2. configure: tiny transformer, AdamA, 4 micro-batches per step
    let cfg = TrainConfig {
        model: "tiny".into(),
        optimizer: OptimizerKind::AdamA,
        accum_steps: 4,
        ..TrainConfig::default()
    };
    let mut trainer = Trainer::new(lib, cfg)?;
    let h = trainer.spec().hyper.clone();
    println!(
        "model '{}': {} params across {} layers (max layer {})",
        trainer.spec().config,
        trainer.spec().total_params(),
        trainer.spec().n_layers(),
        trainer.spec().max_layer_params(),
    );

    // 3. synthetic corpus (sparse Markov language; entropy ≈ ln 4)
    let mut corpus = MarkovCorpus::new(h.vocab, 7, 1);
    println!("corpus entropy floor: {:.3} nats", corpus.entropy());

    // 4. train
    for step in 1..=20u64 {
        let minibatch = corpus.minibatch(4, h.microbatch, h.seq);
        let stats = trainer.train_step(&minibatch)?;
        if step % 5 == 0 || step == 1 {
            println!(
                "step {:>3}  loss {:.4}  lr {:.1e}  {:.0} tok/s",
                stats.step,
                stats.loss,
                stats.lr,
                stats.tokens_per_sec()
            );
        }
    }

    // 5. evaluate + memory report
    let eval = corpus.minibatch(4, h.microbatch, h.seq);
    let (loss, acc) = trainer.eval(&eval)?;
    println!("\neval: loss {loss:.4}, next-token accuracy {:.1}%", 100.0 * acc);
    println!("\n{}", trainer.tracker().report());
    println!(
        "\nAdamA gradient peak = one layer ({} bytes), not the full model ({} bytes)",
        trainer.spec().max_layer_params() * 4,
        trainer.spec().total_params() * 4
    );
    Ok(())
}
