//! End-to-end driver: pretrain the `small` transformer LM (~4.2M params)
//! on a synthetic Markov corpus for a few hundred steps, logging the loss
//! curve, eval metrics, throughput and the measured memory breakdown.
//! This is the run recorded in EXPERIMENTS.md §End-to-end.
//!
//!     cargo run --release --example pretrain_lm -- \
//!         --model small --optimizer adama --accum-steps 4 --steps 300 \
//!         --lr 3e-4 --decay cosine --warmup 20 --total-steps 300 \
//!         --out pretrain_small.csv
//!
//! Flags mirror `TrainConfig::from_args`; `--eval-every` and `--out` are
//! local to this driver.

use std::io::Write;

use adama::config::TrainConfig;
use adama::data::MarkovCorpus;
use adama::runtime::ArtifactLibrary;
use adama::util::cliargs::Args;
use adama::Trainer;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env();
    let mut cfg = TrainConfig::from_args(&args)?;
    if args.get("model").is_none() {
        cfg.model = "small".into();
    }
    if args.get("steps").is_none() {
        cfg.steps = 300;
    }
    let eval_every = args.parse_or("eval-every", 50u64)?;
    let out_path = args.str_or("out", "pretrain_small.csv");

    let lib = ArtifactLibrary::open_default()?;
    let mut trainer = Trainer::new(lib, cfg.clone())?;
    let h = trainer.spec().hyper.clone();
    println!(
        "pretraining '{}' ({:.2}M params, {} blocks, hidden {}, seq {}) with {} N={}",
        cfg.model,
        trainer.spec().total_params() as f64 / 1e6,
        trainer.spec().n_blocks(),
        h.hidden,
        h.seq,
        cfg.optimizer.name(),
        cfg.accum_steps,
    );

    let mut corpus = MarkovCorpus::new(h.vocab, 7, 1);
    let mut heldout = MarkovCorpus::new(h.vocab, 7, 987_654_321);
    let eval_set = heldout.minibatch(8, h.microbatch, h.seq);
    println!("corpus entropy floor: {:.3} nats\n", corpus.entropy());

    let t0 = std::time::Instant::now();
    for step in 1..=cfg.steps {
        let minibatch = corpus.minibatch(cfg.accum_steps, h.microbatch, h.seq);
        let stats = trainer.train_step(&minibatch)?;
        if step % 10 == 0 || step == 1 {
            println!(
                "step {:>4}  loss {:.4}  lr {:.2e}  {:>6.0} tok/s",
                stats.step,
                stats.loss,
                stats.lr,
                stats.tokens_per_sec()
            );
        }
        if step % eval_every == 0 {
            let (el, ea) = trainer.eval(&eval_set)?;
            println!("  -- eval @ {step}: loss {el:.4}, acc {:.1}%", 100.0 * ea);
        }
    }
    let wall = t0.elapsed().as_secs_f64();

    let (el, ea) = trainer.eval(&eval_set)?;
    println!("\nfinal eval: loss {el:.4}, next-token acc {:.1}%", 100.0 * ea);
    println!(
        "entropy floor {:.3} — gap to floor {:.3} nats",
        corpus.entropy(),
        el - corpus.entropy()
    );
    println!("wall clock: {wall:.1}s  ({:.2} steps/s, {:.0} tok/s overall)",
        cfg.steps as f64 / wall,
        trainer.metrics().throughput_tail(cfg.steps as usize));
    println!("\n{}", trainer.tracker().report());

    let mut f = std::fs::File::create(&out_path)?;
    f.write_all(trainer.metrics().to_csv().as_bytes())?;
    println!("\nloss curve written to {out_path}");
    Ok(())
}
